//! L3: the SimplePIM framework — the paper's contribution.
//!
//! [`PimSystem`] bundles the three paper interfaces over the simulated
//! machine and the AOT runtime:
//!
//! * **management** (§3.1): [`management::Management`] —
//!   register/lookup/free of PIM-resident arrays by id;
//! * **communication** (§3.2): [`comm`] (host<->PIM broadcast / scatter
//!   / gather) and [`collectives`] (PIM<->PIM allreduce / allgather via
//!   the host root);
//! * **processing** (§3.3): [`iterators`] (map, general reduction with
//!   shared/private accumulator variants, lazy zip), driven by
//!   [`handle::Handle`]s created from [`handle::PimFunc`] kernel
//!   families.
//!
//! The request path is **plan-based** (DESIGN.md §9): iterator calls
//! build [`plan::PlanNode`]s in a lazy op graph rather than dispatching
//! eagerly.  Map nodes defer their launch and MRAM materialization
//! until forced (by `gather`, a collective, [`PimSystem::run`], or a
//! consuming reduction); the optimizer ([`optimizer`]) then executes
//! map→map / map→red chains as a single fused gang launch with no
//! materialized intermediate, elides dead intermediates, serves
//! repeated reductions from an LRU plan cache, and recycles device
//! buffers and shipped contexts across training-loop iterations.
//!
//! Supporting machinery: [`scheduler`] (tasklet partitioning +
//! WRAM-pressure thread laddering), [`planner`] (scatter padding +
//! dynamic DMA batch sizing, memoized per shape), [`exec`] (gang
//! marshalling through PJRT + single-DPU host evaluation).  *How* those
//! per-DPU loops execute — sequential walk, gang batches, or a
//! rank-sharded `std::thread::scope` worker pool — is the
//! [`crate::backend`] layer's choice (DESIGN.md §11), selected per
//! system via [`PimSystemBuilder::backend`] or the CLI's `--backend` /
//! `--threads` flags.
//!
//! Systems are assembled through one front door,
//! [`PimSystem::builder`]: configuration (runtime, backend, pipeline,
//! shared cache) is stated up front and validated in one place, and
//! both the CLI and the serving layer ([`service::PimService`]) build
//! through it.  The historical constructor zoo
//! (`new`/`with_backend`/`with_backend_shared`) and the post-hoc
//! mutators (`set_backend`/`set_shared_cache`) survive as deprecated
//! delegates.

pub mod collectives;
pub mod comm;
pub mod exec;
pub mod extensions;
pub mod handle;
pub mod iterators;
pub mod jobs;
pub mod management;
pub mod optimizer;
pub mod plan;
pub mod planner;
pub mod scheduler;
pub mod service;
pub mod shared;

pub use handle::{Handle, PimFunc, TransformKind};
pub use jobs::{DeviceReport, JobHandle, JobOutcome, JobPlan, JobQueue, SharedCacheMode};
pub use management::{ArrayMeta, Layout, Management};
pub use plan::{NodeState, PlanNode, PlanOp, PlanStats};
pub use service::{
    poisson_arrivals, ClassReport, JobSpec, JobSpecBuilder, JobTicket, PimService, ResizePolicy,
    SaturationPolicy, ServiceConfig, SlaClass, TicketStatus,
};
pub use shared::{CacheStats, SharedCacheStats, SharedPlanCache};

use std::sync::Arc;

use crate::backend::{BackendKind, BackendStats, ExecBackend};
use crate::error::Result;
use crate::pim::{PimConfig, PimMachine, PipelineMode, Timeline};
use crate::runtime::Runtime;
use crate::timing::{DmaPolicy, OptFlags, ReduceVariant};

/// The assembled SimplePIM system: one simulated PIM machine, the
/// host-side management registry, the plan engine, the execution
/// backend, and (optionally) the PJRT runtime executing the
/// AOT-compiled kernels.
pub struct PimSystem {
    pub machine: PimMachine,
    pub management: Management,
    pub(crate) runtime: Option<Runtime>,
    /// How per-DPU kernel invocations and row-marshalling loops execute
    /// on the host (sequential walk / gang batching / rank-sharded
    /// workers).  Functional strategy only: modeled time never depends
    /// on it (see `rust/tests/backend_parity.rs`).
    pub(crate) backend: Box<dyn ExecBackend>,
    /// The plan-based execution engine: lazy op graph, pending
    /// (deferred) maps, plan cache, buffer/context pools.
    pub(crate) engine: plan::PlanEngine,
    /// Pipelined transfer engine mode (DESIGN.md §12): Off = the
    /// monolithic scatter-all → run-all → gather-all request path; On /
    /// Auto defer scatter charges and overlap chunked transfers with
    /// kernel execution at forcing boundaries.  Results are
    /// bit-identical in every mode (rust/tests/backend_parity.rs).
    pub(crate) pipeline: PipelineMode,
    /// Code-optimization flags the framework "compiles" kernels with
    /// (all on by default; the ablation bench toggles them).
    pub opts: OptFlags,
    /// Tasklets requested per DPU (paper default: 12).
    pub tasklets: u32,
    /// DMA batch policy (Dynamic unless ablating §4.3.5).
    pub dma_policy: DmaPolicy,
    /// Force a reduction variant (Fig. 11 sweeps); `None` = automatic.
    pub red_variant_override: Option<ReduceVariant>,
    /// Variant + active tasklets of the most recent `array_red`.
    pub last_red_variant: Option<(ReduceVariant, u32)>,
    /// Static-verifier enforcement (DESIGN.md §19): `Off` skips the
    /// pass entirely; `Warn` reports findings on stderr; `Deny` refuses
    /// plans with error-severity findings at the forcing boundaries.
    pub(crate) analyze: crate::analysis::AnalyzeMode,
    /// Findings already reported this session (the verifier re-lints
    /// the whole graph at every boundary; each unique finding prints
    /// once).
    pub(crate) analyze_reported: std::collections::HashSet<String>,
}

impl std::fmt::Debug for PimSystem {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PimSystem")
            .field("dpus", &self.machine.cfg.n_dpus)
            .field("backend", &self.backend.kind())
            .field("pipeline", &self.pipeline)
            .field("analyze", &self.analyze)
            .field("arrays", &self.management.ids().len())
            .field("plan_nodes", &self.engine.graph.len())
            .finish_non_exhaustive()
    }
}

/// How a [`PimSystemBuilder`] decides on the AOT runtime.
enum RuntimeSpec {
    /// Load from the default artifact directory; failure is the
    /// builder's error.
    Load,
    /// Try to load, silently falling back to host-golden execution.
    LoadOrHost,
    /// Use exactly this runtime decision (`None` = host-only).
    Explicit(Option<Runtime>),
}

/// How a [`PimSystemBuilder`] decides on the execution backend.
enum BackendSpec {
    /// `SIMPLEPIM_BACKEND` / `SIMPLEPIM_THREADS`, defaulting to the
    /// sequential walk — what lets CI run the whole suite under
    /// `--backend parallel` without touching test code.
    Env,
    /// An already-built instance (arena pools and counters carried in).
    Instance(Box<dyn ExecBackend>),
    /// Build `kind` with `threads` workers at `build()` time.
    Kind(BackendKind, usize),
}

/// One front door for assembling a [`PimSystem`] (DESIGN.md §17): the
/// runtime decision, the execution backend, the pipelined transfer
/// mode, and the cross-tenant shared plan cache are all stated here
/// and validated by [`Self::build`].
///
/// Environment coupling is explicit: with no backend stated, the
/// backend and pipeline come from `SIMPLEPIM_BACKEND` /
/// `SIMPLEPIM_THREADS` / `SIMPLEPIM_PIPELINE` (resolved through
/// [`crate::util::settings`]); stating a backend opts the system out
/// of the environment entirely (pipeline defaults to `Off` unless
/// stated), so callers that validated their own selection — the
/// serving layer's admission engine — cannot be failed mid-run by
/// garbage in the environment.
pub struct PimSystemBuilder {
    cfg: PimConfig,
    runtime: RuntimeSpec,
    backend: BackendSpec,
    pipeline: Option<PipelineMode>,
    shared: Option<Arc<SharedPlanCache>>,
    analyze: Option<crate::analysis::AnalyzeMode>,
}

impl std::fmt::Debug for PimSystemBuilder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PimSystemBuilder")
            .field("dpus", &self.cfg.n_dpus)
            .field("pipeline", &self.pipeline)
            .field("analyze", &self.analyze)
            .field("shared_cache", &self.shared.is_some())
            .finish_non_exhaustive()
    }
}

impl PimSystemBuilder {
    /// Load the AOT runtime from the default artifact directory
    /// (`$SIMPLEPIM_ARTIFACTS` or `./artifacts`); a missing or
    /// malformed manifest fails `build()`.
    pub fn load_runtime(mut self) -> Self {
        self.runtime = RuntimeSpec::Load;
        self
    }

    /// Load the AOT runtime if available, else fall back to the
    /// bit-identical host goldens.
    pub fn load_runtime_or_host(mut self) -> Self {
        self.runtime = RuntimeSpec::LoadOrHost;
        self
    }

    /// Use exactly this runtime decision (`None` = host-only, the
    /// default).
    pub fn runtime(mut self, runtime: Option<Runtime>) -> Self {
        self.runtime = RuntimeSpec::Explicit(runtime);
        self
    }

    /// Use an already-built execution backend instance (its
    /// `backend::arena` staging pools and counters carry over — the
    /// serving layer reuses one instance across a worker's whole job
    /// stream).  Opts out of the `SIMPLEPIM_*` environment.
    pub fn backend(mut self, backend: Box<dyn ExecBackend>) -> Self {
        self.backend = BackendSpec::Instance(backend);
        self
    }

    /// Build a backend of `kind` with `threads` workers at `build()`
    /// time (invalid combinations fail there).  Opts out of the
    /// `SIMPLEPIM_*` environment.
    pub fn backend_kind(mut self, kind: BackendKind, threads: usize) -> Self {
        self.backend = BackendSpec::Kind(kind, threads);
        self
    }

    /// Select the pipelined transfer mode explicitly.
    pub fn pipeline(mut self, mode: PipelineMode) -> Self {
        self.pipeline = Some(mode);
        self
    }

    /// Install a cross-tenant shared plan cache handle (DESIGN.md §16);
    /// `None` — the default — is the private single-tenant cache.
    pub fn shared_cache(mut self, shared: Option<Arc<SharedPlanCache>>) -> Self {
        self.shared = shared;
        self
    }

    /// Select the static-verifier mode explicitly (DESIGN.md §19).
    /// Unlike the backend knob, `SIMPLEPIM_ANALYZE` is consulted even
    /// for explicitly-configured systems when this is not called — the
    /// verifier is an observer that never changes results or modeled
    /// time on clean plans, so environment opt-in is always safe.
    pub fn analyze(mut self, mode: crate::analysis::AnalyzeMode) -> Self {
        self.analyze = Some(mode);
        self
    }

    /// Validate the configuration and assemble the system.
    pub fn build(self) -> Result<PimSystem> {
        let runtime = match self.runtime {
            RuntimeSpec::Load => Some(Runtime::load(Runtime::default_dir())?),
            RuntimeSpec::LoadOrHost => Runtime::load(Runtime::default_dir()).ok(),
            RuntimeSpec::Explicit(rt) => rt,
        };
        let (backend, explicit) = match self.backend {
            BackendSpec::Env => {
                let kind = std::env::var(crate::util::settings::ENV_BACKEND).ok();
                let threads = std::env::var(crate::util::settings::ENV_THREADS).ok();
                let (kind, threads) =
                    crate::backend::resolve_env(kind.as_deref(), threads.as_deref())?;
                (crate::backend::make(kind, threads)?, false)
            }
            BackendSpec::Instance(b) => (b, true),
            BackendSpec::Kind(kind, threads) => (crate::backend::make(kind, threads)?, true),
        };
        let pipeline = match self.pipeline {
            Some(mode) => mode,
            // An explicitly-chosen backend opts out of the environment
            // wholesale; otherwise the pipeline knob follows it too.
            None if explicit => PipelineMode::Off,
            None => crate::util::settings::pipeline_from_env()?,
        };
        let analyze = match self.analyze {
            Some(mode) => mode,
            None => crate::util::settings::analyze_from_env()?,
        };
        let mut sys = assemble(self.cfg, runtime, backend, self.shared);
        sys.pipeline = pipeline;
        sys.analyze = analyze;
        Ok(sys)
    }
}

/// The one place a [`PimSystem`] is actually put together (every
/// constructor — current and deprecated — funnels here).
fn assemble(
    cfg: PimConfig,
    runtime: Option<Runtime>,
    backend: Box<dyn ExecBackend>,
    shared: Option<Arc<SharedPlanCache>>,
) -> PimSystem {
    let tasklets = cfg.default_tasklets;
    let mut engine = plan::PlanEngine::new();
    engine.shared = shared;
    PimSystem {
        machine: PimMachine::new(cfg),
        management: Management::new(),
        runtime,
        backend,
        engine,
        pipeline: PipelineMode::Off,
        opts: OptFlags::simplepim(),
        tasklets,
        dma_policy: DmaPolicy::Dynamic,
        red_variant_override: None,
        last_red_variant: None,
        analyze: crate::analysis::AnalyzeMode::Off,
        analyze_reported: std::collections::HashSet::new(),
    }
}

impl PimSystem {
    /// Start building a system over `cfg` (host-only, environment
    /// backend/pipeline, no shared cache until stated otherwise).
    pub fn builder(cfg: PimConfig) -> PimSystemBuilder {
        PimSystemBuilder {
            cfg,
            runtime: RuntimeSpec::Explicit(None),
            backend: BackendSpec::Env,
            pipeline: None,
            shared: None,
            analyze: None,
        }
    }

    /// Build a system with the AOT runtime loaded from the default
    /// artifact directory (`$SIMPLEPIM_ARTIFACTS` or `./artifacts`).
    #[deprecated(
        since = "0.3.0",
        note = "use `PimSystem::builder(cfg).load_runtime().build()`"
    )]
    pub fn new(cfg: PimConfig) -> Result<Self> {
        Self::builder(cfg).load_runtime().build()
    }

    /// Build a system that executes kernels with the bit-identical host
    /// goldens instead of PJRT (no artifacts needed; used by unit tests
    /// and available as a deployment mode).
    pub fn host_only(cfg: PimConfig) -> Self {
        // Environment garbage aborts loudly, exactly like the historical
        // `backend::from_env` path this infallible signature wrapped.
        Self::builder(cfg).build().unwrap_or_else(|e| panic!("{e}"))
    }

    /// [`Self::builder`] + `load_runtime`, silently falling back to the
    /// host execution engine when the PJRT runtime is unavailable
    /// (missing artifacts or a build without the `pjrt` feature).  The
    /// convenience constructor examples and tests use.
    pub fn new_or_host(cfg: PimConfig) -> Self {
        Self::builder(cfg)
            .load_runtime_or_host()
            .build()
            .unwrap_or_else(|e| panic!("{e}"))
    }

    /// Build with an explicit (possibly shared) runtime decision.  The
    /// execution backend comes from the environment
    /// (`SIMPLEPIM_BACKEND` / `SIMPLEPIM_THREADS`), defaulting to the
    /// sequential walk.
    pub fn with_runtime(cfg: PimConfig, runtime: Option<Runtime>) -> Self {
        Self::builder(cfg).runtime(runtime).build().unwrap_or_else(|e| panic!("{e}"))
    }

    /// Build with an explicit execution backend.  Consults no
    /// `SIMPLEPIM_*` environment at all (pipeline defaults to `Off`).
    #[deprecated(
        since = "0.3.0",
        note = "use `PimSystem::builder(cfg).runtime(rt).backend(b).build()`"
    )]
    pub fn with_backend(
        cfg: PimConfig,
        runtime: Option<Runtime>,
        backend: Box<dyn ExecBackend>,
    ) -> Self {
        assemble(cfg, runtime, backend, None)
    }

    /// `with_backend` with a cross-tenant shared plan cache handle
    /// installed at construction (DESIGN.md §16).
    #[deprecated(
        since = "0.3.0",
        note = "use `PimSystem::builder(cfg).runtime(rt).backend(b).shared_cache(c).build()`"
    )]
    pub fn with_backend_shared(
        cfg: PimConfig,
        runtime: Option<Runtime>,
        backend: Box<dyn ExecBackend>,
        shared: Option<Arc<SharedPlanCache>>,
    ) -> Self {
        assemble(cfg, runtime, backend, shared)
    }

    /// Install (or remove) the cross-tenant shared plan cache.  Safe at
    /// any point: sharing never changes a result bit, only where
    /// reduction plans are looked up and whether the sharing ledger
    /// records.
    #[deprecated(
        since = "0.3.0",
        note = "state the cache at construction: `PimSystem::builder(cfg).shared_cache(c).build()`"
    )]
    pub fn set_shared_cache(&mut self, shared: Option<Arc<SharedPlanCache>>) {
        self.engine.shared = shared;
    }

    /// The installed shared plan cache, if any.
    pub fn shared_cache(&self) -> Option<&Arc<SharedPlanCache>> {
        self.engine.shared.as_ref()
    }

    /// This system's plan-cache counters (the per-tenant view),
    /// deliberately separate from the timeline: [`Self::reset_timeline`]
    /// measurement boundaries never touch them.  Hits/misses count this
    /// system's lookups wherever they were served (private or shared);
    /// evictions are a property of the cache itself, so under a shared
    /// cache they live in [`SharedPlanCache::stats`] and are reported 0
    /// here.
    pub fn cache_stats(&self) -> CacheStats {
        CacheStats {
            hits: self.engine.stats.cache_hits,
            misses: self.engine.stats.cache_misses,
            evictions: if self.engine.shared.is_some() {
                0
            } else {
                self.engine.cache.evictions()
            },
        }
    }

    /// Take this system's sharing ledger (broadcast ships + launch
    /// fingerprint), leaving an empty one.  The job scheduler reads it
    /// after a job completes; empty unless a shared cache is installed.
    pub(crate) fn take_sharing_ledger(&mut self) -> shared::SharingLedger {
        std::mem::take(&mut self.engine.ledger)
    }

    /// Swap the execution backend (results and modeled time are
    /// backend-invariant, so this is safe at any point).
    #[deprecated(
        since = "0.3.0",
        note = "state the backend at construction: `PimSystem::builder(cfg).backend(b).build()`"
    )]
    pub fn set_backend(&mut self, backend: Box<dyn ExecBackend>) {
        self.backend = backend;
    }

    /// Which backend executes kernels and marshalling loops.
    pub fn backend_kind(&self) -> BackendKind {
        self.backend.kind()
    }

    /// Decompose the system, handing back its execution backend so a
    /// job-scheduler worker ([`jobs::JobQueue`]) can reuse one backend
    /// instance — and its `backend::arena` staging pools — across
    /// successive jobs instead of rebuilding it per job.
    pub fn into_backend(self) -> Box<dyn ExecBackend> {
        self.backend
    }

    /// Select the pipelined execution mode (CLI: `--pipeline`).
    /// Results are mode-invariant; only the modeled overlap changes.
    /// Turning the pipeline off first flushes any deferred scatter
    /// charges so no modeled time is lost at the transition.
    pub fn set_pipeline(&mut self, mode: PipelineMode) -> Result<()> {
        if mode == PipelineMode::Off {
            self.flush_all_xfers();
        }
        self.pipeline = mode;
        Ok(())
    }

    /// The active pipelined execution mode.
    pub fn pipeline_mode(&self) -> PipelineMode {
        self.pipeline
    }

    /// Worker threads the backend shards across (1 for seq/gang).
    pub fn backend_threads(&self) -> usize {
        self.backend.threads()
    }

    /// Backend counters (launches, host lanes, gang batches, sharded
    /// operations).
    pub fn backend_stats(&self) -> BackendStats {
        self.backend.stats()
    }

    /// Create a function handle
    /// (paper: `simple_pim_create_handle(filepath, type, data, size)`).
    pub fn create_handle(
        &self,
        func: PimFunc,
        kind: TransformKind,
        ctx: Vec<i32>,
    ) -> Result<Handle> {
        Handle::create(func, kind, ctx)
    }

    /// Arm deterministic fault injection on this system's machine
    /// (DESIGN.md §18): fork the plan's seeded stream with `salt` (the
    /// scheduler passes the job's submission index) under `policy`.
    /// Every timed launch and transfer then runs behind the fault
    /// guard; with no plan installed the guards are single branches
    /// and every path stays bit- and timeline-identical.
    pub fn install_faults(
        &mut self,
        spec: &crate::pim::FaultSpec,
        salt: u64,
        policy: crate::pim::RecoveryPolicy,
    ) {
        self.machine.install_faults(spec, salt, policy);
    }

    /// Faults injected into this system so far, in injection order.
    pub fn fault_events(&self) -> &[crate::pim::FaultEvent] {
        self.machine.fault_events()
    }

    /// Select the static-verifier mode (CLI: `--analyze`, DESIGN.md
    /// §19).  A pure read-only pass at the forcing boundaries: clean
    /// plans are bit- and timeline-identical under every mode.
    pub fn set_analyze(&mut self, mode: crate::analysis::AnalyzeMode) {
        self.analyze = mode;
    }

    /// The active static-verifier mode.
    pub fn analyze_mode(&self) -> crate::analysis::AnalyzeMode {
        self.analyze
    }

    /// Toggle the debug sanitizer (DESIGN.md §19): while on, every
    /// coordinator-level MRAM transfer records its direction, address,
    /// row shape, and FNV checksum for [`Self::sanitizer_report`] to
    /// cross-check.  Functional recording only — the timeline is never
    /// touched — but it allocates, so it stays opt-in and is *not*
    /// implied by `deny`.
    pub fn set_sanitizer(&mut self, on: bool) {
        self.machine.set_sanitizer(on);
    }

    /// Audit the sanitizer's transfer log (SP201/SP202).
    pub fn sanitizer_report(&self) -> crate::analysis::Report {
        crate::analysis::audit_transfers(self.machine.xfer_log())
    }

    /// The analyzable event program for this session: the plan graph's
    /// nodes interleaved with the engine's free records, with element
    /// sizes resolved from the management registry where still known.
    pub fn analysis_program(&self) -> crate::analysis::Program {
        crate::analysis::Program::from_graph(&self.engine.graph, &self.engine.frees, |array| {
            self.management.lookup(array).map(|m| m.type_size).unwrap_or(0)
        })
    }

    /// Run every applicable static check over the current session:
    /// dataflow lint + fusion-legality audit, plus the sanitizer audit
    /// when its log is active.  Returns an empty report when the graph
    /// overflowed its recording bound — a truncated program cannot be
    /// reasoned about without false positives.
    pub fn analysis_report(&self) -> crate::analysis::Report {
        if self.engine.graph.dropped > 0 {
            return crate::analysis::Report::default();
        }
        let mut report = crate::analysis::verify_program(&self.analysis_program());
        if self.machine.sanitizer_enabled() {
            report.merge(self.sanitizer_report());
        }
        report
    }

    /// The enforcement hook called at the forcing boundaries
    /// ([`Self::run`], `gather`): no-op when `Off`; otherwise lint,
    /// report each unique finding once on stderr, and under `Deny`
    /// refuse the plan on error-severity findings.
    pub(crate) fn verify_plan(&mut self) -> Result<()> {
        use crate::analysis::AnalyzeMode;
        if self.analyze == AnalyzeMode::Off {
            return Ok(());
        }
        let report = self.analysis_report();
        if report.is_clean() {
            return Ok(());
        }
        for d in &report.diagnostics {
            if self.analyze_reported.insert(d.to_string()) {
                eprintln!("simplepim: analyze: {d}");
            }
        }
        if self.analyze == AnalyzeMode::Deny {
            report.into_result()?;
        }
        Ok(())
    }

    /// Modeled end-to-end timeline so far.
    pub fn timeline(&self) -> Timeline {
        self.machine.timeline()
    }

    /// Reset the modeled timeline (functional state is kept).
    /// Deferred pipelined scatter charges are flushed first so they
    /// land in the pre-reset era — exactly where the monolithic path
    /// charged them — and can never leak across a measurement boundary
    /// (which would make a reset-delimited pipelined region model
    /// *slower* than the monolithic one).
    pub fn reset_timeline(&mut self) {
        self.flush_all_xfers();
        self.machine.reset_timeline();
    }

    /// Whether kernels execute through the PJRT runtime (vs host
    /// fallback).
    pub fn has_runtime(&self) -> bool {
        self.runtime.is_some()
    }

    /// Executor statistics (zero when running host-only).
    pub fn exec_stats(&self) -> crate::runtime::ExecStats {
        self.runtime.as_ref().map(|r| r.stats()).unwrap_or_default()
    }
}
