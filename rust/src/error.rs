//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls: the build environment has no
//! crates.io access, so `thiserror` is unavailable.

use std::fmt;

/// Errors produced by the SimplePIM framework.
#[derive(Debug)]
pub enum Error {
    /// Error bubbled up from the XLA/PJRT runtime (or its absence when
    /// the crate is built without the `pjrt` feature).
    Xla(String),

    /// I/O error (artifact files, source files for LoC counting, ...).
    Io(std::io::Error),

    /// Malformed manifest or other JSON input.
    Json(String),

    /// Lookup of an array id that is not registered (paper: `lookup`).
    UnknownArray(String),

    /// An array id was registered twice without an intervening `free`.
    DuplicateArray(String),

    /// Data transfer violating the PIM system's alignment constraints.
    Alignment(String),

    /// Out of MRAM/WRAM capacity on a simulated bank.
    Capacity(String),

    /// No AOT artifact satisfies the request (wrong shape family, missing
    /// manifest entry, or `make artifacts` not run).
    Artifact(String),

    /// Handle/iterator misuse (wrong transformation type, arity, ...).
    Handle(String),

    /// Invalid runtime configuration (backend/thread/pipeline selection
    /// via CLI flags or `SIMPLEPIM_*` environment variables).  Always
    /// carries the offending value: the execution strategies are
    /// parity-identical by design, so a silently corrected typo would
    /// run the wrong path with everything green.
    Config(String),

    /// An injected hardware fault exhausted its operation's retry
    /// budget (DESIGN.md §18): the dead-letter path.  Carries the
    /// fault history (kind, rank, virtual time, attempt) so the
    /// failure is attributable; the scheduler's partition prefix
    /// completes the rank + partition attribution.
    Fault(String),

    /// A job closure panicked inside a partition worker.  The panic is
    /// caught at the execution boundary so one misbehaving tenant
    /// cannot poison the shared service lock for every other producer;
    /// carries the job name.
    JobPanicked(String),

    /// The serving layer's bounded admission queue is full and the
    /// saturation policy is `Reject`: the submission was refused, not
    /// queued.  Callers retry, shed load, or switch the service to the
    /// blocking policy — silently growing the queue would hide device
    /// saturation until every deadline was already blown.
    Saturated(String),

    /// The static verifier (DESIGN.md §19) found error-severity
    /// diagnostics and the analyze mode is `deny`: the plan is refused
    /// before execution.  Carries the finding count and the first
    /// diagnostic with its stable `SPxxx` code.
    Analysis(String),

    /// Anything else.
    Msg(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Xla(e) => write!(f, "xla: {e}"),
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Json(e) => write!(f, "json: {e}"),
            Error::UnknownArray(id) => write!(f, "unknown array id: {id}"),
            Error::DuplicateArray(id) => write!(f, "duplicate array id: {id}"),
            Error::Alignment(e) => write!(f, "alignment: {e}"),
            Error::Capacity(e) => write!(f, "capacity: {e}"),
            Error::Artifact(e) => write!(f, "artifact: {e}"),
            Error::Handle(e) => write!(f, "handle: {e}"),
            Error::Config(e) => write!(f, "config: {e}"),
            Error::Fault(e) => write!(f, "fault: {e}"),
            Error::JobPanicked(name) => write!(f, "job panicked: {name}"),
            Error::Saturated(e) => write!(f, "saturated: {e}"),
            Error::Analysis(e) => write!(f, "analysis: {e}"),
            Error::Msg(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl Error {
    pub fn msg(s: impl Into<String>) -> Self {
        Error::Msg(s.into())
    }
}

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes_match_variant() {
        assert_eq!(Error::UnknownArray("t".into()).to_string(), "unknown array id: t");
        assert_eq!(Error::Alignment("bad".into()).to_string(), "alignment: bad");
        assert_eq!(Error::Config("bad knob".into()).to_string(), "config: bad knob");
        assert_eq!(
            Error::Saturated("queue full (depth 4)".into()).to_string(),
            "saturated: queue full (depth 4)"
        );
        assert_eq!(
            Error::Fault("dead-letter after 3 retries".into()).to_string(),
            "fault: dead-letter after 3 retries"
        );
        assert_eq!(Error::JobPanicked("mlp#2".into()).to_string(), "job panicked: mlp#2");
        assert_eq!(
            Error::Analysis("1 finding(s), first: [SP002] ...".into()).to_string(),
            "analysis: 1 finding(s), first: [SP002] ..."
        );
        assert_eq!(Error::msg("plain").to_string(), "plain");
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(e.to_string().starts_with("io: "));
        assert!(std::error::Error::source(&e).is_some());
    }
}
