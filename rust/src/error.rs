//! Crate-wide error type.

use thiserror::Error;

/// Errors produced by the SimplePIM framework.
#[derive(Error, Debug)]
pub enum Error {
    /// Error bubbled up from the XLA/PJRT runtime.
    #[error("xla: {0}")]
    Xla(#[from] xla::Error),

    /// I/O error (artifact files, source files for LoC counting, ...).
    #[error("io: {0}")]
    Io(#[from] std::io::Error),

    /// Malformed manifest or other JSON input.
    #[error("json: {0}")]
    Json(String),

    /// Lookup of an array id that is not registered (paper: `lookup`).
    #[error("unknown array id: {0}")]
    UnknownArray(String),

    /// An array id was registered twice without an intervening `free`.
    #[error("duplicate array id: {0}")]
    DuplicateArray(String),

    /// Data transfer violating the PIM system's alignment constraints.
    #[error("alignment: {0}")]
    Alignment(String),

    /// Out of MRAM/WRAM capacity on a simulated bank.
    #[error("capacity: {0}")]
    Capacity(String),

    /// No AOT artifact satisfies the request (wrong shape family, missing
    /// manifest entry, or `make artifacts` not run).
    #[error("artifact: {0}")]
    Artifact(String),

    /// Handle/iterator misuse (wrong transformation type, arity, ...).
    #[error("handle: {0}")]
    Handle(String),

    /// Anything else.
    #[error("{0}")]
    Msg(String),
}

impl Error {
    pub fn msg(s: impl Into<String>) -> Self {
        Error::Msg(s.into())
    }
}

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;
