//! SimplePIM CLI — run workloads, regenerate the paper's tables and
//! figures, inspect the machine model.

fn main() {
    if let Err(e) = simplepim::cli::run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
