//! WRAM<->MRAM DMA engine: constraint checking + cost model.
//!
//! UPMEM's `mram_read`/`mram_write` require 8-byte alignment and cap a
//! single transfer at 2,048 bytes; latency is a fixed setup plus a
//! per-byte streaming cost, so *larger batches amortize the setup* — the
//! mechanism behind paper §4.3 optimization 5 (dynamic transfer sizing)
//! and the PrIM observation that transfer size strongly affects
//! bandwidth.

use crate::error::{Error, Result};

use super::config::PimConfig;

/// Validate one DMA transfer against the hardware constraints.
pub fn check_transfer(cfg: &PimConfig, mram_addr: u64, bytes: u64) -> Result<()> {
    if bytes == 0 {
        return Err(Error::Alignment("zero-length DMA".into()));
    }
    if mram_addr % cfg.dma_align != 0 {
        return Err(Error::Alignment(format!(
            "MRAM address {mram_addr:#x} not {}-byte aligned",
            cfg.dma_align
        )));
    }
    if bytes % cfg.dma_align != 0 {
        return Err(Error::Alignment(format!(
            "DMA size {bytes} not a multiple of {}",
            cfg.dma_align
        )));
    }
    if bytes > cfg.dma_max_bytes {
        return Err(Error::Alignment(format!(
            "DMA size {bytes} exceeds the {}-byte limit",
            cfg.dma_max_bytes
        )));
    }
    Ok(())
}

/// Cycles for a single DMA of `bytes` (must already satisfy constraints).
pub fn transfer_cycles(cfg: &PimConfig, bytes: u64) -> f64 {
    cfg.dma_setup_cycles as f64 + bytes as f64 / cfg.dma_bytes_per_cycle
}

/// Cycles to stream `total_bytes` through WRAM in batches of
/// `batch_bytes` (the planner guarantees `batch_bytes` is legal).
///
/// The last batch may be short; it still pays the full setup.
pub fn stream_cycles(cfg: &PimConfig, total_bytes: u64, batch_bytes: u64) -> f64 {
    if total_bytes == 0 {
        return 0.0;
    }
    let batch = batch_bytes.clamp(cfg.dma_align, cfg.dma_max_bytes);
    let full = total_bytes / batch;
    let tail = total_bytes % batch;
    let mut cycles = full as f64 * transfer_cycles(cfg, batch);
    if tail > 0 {
        cycles += transfer_cycles(cfg, crate::util::round_up(tail, cfg.dma_align));
    }
    cycles
}

/// Effective DMA bandwidth (bytes/cycle) at a given batch size — useful
/// for reporting and for the ablation bench.
pub fn effective_bandwidth(cfg: &PimConfig, batch_bytes: u64) -> f64 {
    batch_bytes as f64 / transfer_cycles(cfg, batch_bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> PimConfig {
        PimConfig::upmem(64)
    }

    #[test]
    fn rejects_misaligned() {
        let c = cfg();
        assert!(check_transfer(&c, 4, 64).is_err()); // bad address
        assert!(check_transfer(&c, 8, 60).is_err()); // bad size
        assert!(check_transfer(&c, 8, 0).is_err()); // zero
        assert!(check_transfer(&c, 8, 4096).is_err()); // over the cap
        assert!(check_transfer(&c, 8, 2048).is_ok());
    }

    #[test]
    fn bigger_batches_amortize_setup() {
        // The crux of paper §4.3 optimization 5.
        let c = cfg();
        let bw_small = effective_bandwidth(&c, 64);
        let bw_big = effective_bandwidth(&c, 2048);
        assert!(bw_big > 2.0 * bw_small, "{bw_big} vs {bw_small}");
    }

    #[test]
    fn stream_accounts_tail() {
        let c = cfg();
        let full_only = stream_cycles(&c, 4096, 2048);
        let with_tail = stream_cycles(&c, 4096 + 8, 2048);
        assert!(with_tail > full_only);
        // Tail costs one extra setup plus 8 bytes of streaming.
        let expected = full_only + transfer_cycles(&c, 8);
        assert!((with_tail - expected).abs() < 1e-9);
    }

    #[test]
    fn zero_bytes_cost_nothing() {
        assert_eq!(stream_cycles(&cfg(), 0, 2048), 0.0);
    }

    #[test]
    fn streaming_monotone_in_total() {
        let c = cfg();
        let mut last = 0.0;
        for kb in 1..16 {
            let t = stream_cycles(&c, kb * 1024, 2048);
            assert!(t > last);
            last = t;
        }
    }
}
