//! Two pipelines live here.
//!
//! **The DPU's 11-stage fine-grained multithreaded pipeline model.**
//! The UPMEM DPU interleaves tasklets in a "revolver" scheme: a given
//! tasklet may have at most one instruction in flight, so it can issue at
//! most once every `pipeline_depth` (11) cycles.  With `T` tasklets the
//! core's issue throughput is `min(T, 11) / 11` instructions per cycle —
//! at least 11 tasklets keep the pipeline full (paper §2, [26, 53]).
//! This single mechanism produces the paper's Fig. 11 behaviour: when the
//! thread-private reduction variant must drop from 12 to 8/4/2 active
//! tasklets (WRAM pressure), execution time grows inversely with the
//! issue rate — "the reduction in the number of active threads causes a
//! linear increase of the execution time".
//!
//! **The pipelined transfer engine's chunk scheduler (DESIGN.md §12).**
//! Real UPMEM ranks can overlap host↔PIM transfers of one buffer region
//! with kernel execution over another, but the monolithic request path
//! serializes scatter-all → run-all → gather-all.  The types below split
//! per-DPU rows into fixed-size chunks and model a three-lane,
//! double-buffered software pipeline — chunk `k+1` scatter and chunk
//! `k−1` gather run concurrently with the kernel execution of chunk `k`
//! — so overlapped phases are charged as `max(xfer, exec)` per chunk
//! instead of their sum:
//!
//! * [`ChunkPlan`] — logical row spans for the *functional* chunked
//!   execution (`ExecBackend::launch_pipelined`) and the chunked
//!   scatter/gather byte staging ([`byte_spans`]);
//! * [`schedule`] — the *cost model*: searches candidate chunk counts
//!   (1 = monolithic is always a candidate, so a pipelined launch can
//!   never model slower than the monolithic one), simulates the
//!   in/exec/out lanes under the configured in-flight window
//!   ([`makespan`]), and reports the critical path plus the seconds
//!   saved by overlap;
//! * [`PipelineMode`] — the `--pipeline {off,on,auto}` /
//!   `SIMPLEPIM_PIPELINE` switch: `on` pipelines every structurally
//!   eligible launch, `auto` lets the planner restructure only when the
//!   estimated win clears a latency-scaled threshold.
//!
//! Every chunk's transfer cost routes through
//! [`transfer_seconds`], so under an explicit channel→rank→DPU
//! topology (DESIGN.md §15) each chunk is charged against all the rank
//! engines it spans — the scheduler's per-chunk transfer lanes shrink
//! by the rank fan-out, and its chunk-count search rebalances
//! accordingly.  Nothing here assumes a single flat bus.

use crate::error::{Error, Result};

use super::config::PimConfig;
use super::xfer::{transfer_seconds, XferKind};

/// Issue throughput in instructions/cycle for `tasklets` active threads.
pub fn issue_rate(cfg: &PimConfig, tasklets: u32) -> f64 {
    assert!(tasklets >= 1, "at least one tasklet must run");
    let t = tasklets.min(cfg.pipeline_depth);
    t as f64 / cfg.pipeline_depth as f64
}

/// Cycles to retire `slots` issue slots with `tasklets` active threads.
///
/// `slots` is the *total* over all tasklets (the work is pre-partitioned
/// evenly, so per-tasklet imbalance is at most one batch and ignored
/// here; the scheduler accounts for the trailing remainder separately).
pub fn cycles(cfg: &PimConfig, slots: f64, tasklets: u32) -> f64 {
    slots / issue_rate(cfg, tasklets)
}

/// Seconds to retire `slots` issue slots with `tasklets` active threads.
pub fn seconds(cfg: &PimConfig, slots: f64, tasklets: u32) -> f64 {
    cycles(cfg, slots, tasklets) / cfg.freq_hz
}

// ---------------------------------------------------------------------
// Pipelined transfer engine: chunk plans, the double-buffered lane
// simulation, and the chunk-count cost model.
// ---------------------------------------------------------------------

/// Whether (and how) the coordinator pipelines launches
/// (CLI: `--pipeline`, env: `SIMPLEPIM_PIPELINE`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PipelineMode {
    /// Monolithic scatter-all → run-all → gather-all (the seed's
    /// behavior, and the default).
    Off,
    /// Pipeline every structurally eligible launch.  The chunk-count
    /// search always includes the monolithic candidate, so `on` never
    /// models slower than `off`.
    On,
    /// The planner decides per node: pipeline only when the cost
    /// estimate predicts a win above a latency-scaled threshold.
    Auto,
}

impl PipelineMode {
    pub fn parse(s: &str) -> Result<PipelineMode> {
        match s {
            "off" => Ok(PipelineMode::Off),
            "on" => Ok(PipelineMode::On),
            "auto" => Ok(PipelineMode::Auto),
            other => Err(Error::Config(format!(
                "invalid pipeline mode `{other}` (expected off, on, or auto)"
            ))),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            PipelineMode::Off => "off",
            PipelineMode::On => "on",
            PipelineMode::Auto => "auto",
        }
    }
}

impl std::fmt::Display for PipelineMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Process-default pipeline mode: `SIMPLEPIM_PIPELINE` (off | on |
/// auto) when set, else `Off`.  Invalid values are a hard error for the
/// same reason `backend::from_env` makes them one: a typo that silently
/// fell back would run the monolithic path with everything green and
/// zero pipeline coverage.
pub fn mode_from_env() -> PipelineMode {
    crate::util::settings::pipeline_from_env().unwrap_or_else(|e| panic!("{e}"))
}

/// Logical row spans of one chunked launch: each `(lo, hi)` is a
/// half-open range of per-DPU element rows, in execution order.  Spans
/// partition `0..rows`; DPUs holding fewer rows clamp each span to
/// their own length (ragged distributions).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChunkPlan {
    /// Largest per-DPU logical row count the plan covers.
    pub rows: u64,
    /// Half-open row spans, ascending and contiguous.
    pub spans: Vec<(u64, u64)>,
}

impl ChunkPlan {
    /// One chunk covering everything (the degenerate plan).
    pub fn monolithic(rows: u64) -> ChunkPlan {
        ChunkPlan { rows, spans: vec![(0, rows)] }
    }

    /// Split `rows` into at most `chunks` contiguous, near-equal spans.
    pub fn split(rows: u64, chunks: usize) -> ChunkPlan {
        let c = (chunks as u64).clamp(1, rows.max(1));
        if c <= 1 {
            return ChunkPlan::monolithic(rows);
        }
        let base = rows / c;
        let extra = rows % c;
        let mut spans = Vec::with_capacity(c as usize);
        let mut lo = 0u64;
        for i in 0..c {
            let hi = lo + base + u64::from(i < extra);
            spans.push((lo, hi));
            lo = hi;
        }
        debug_assert_eq!(lo, rows);
        ChunkPlan { rows, spans }
    }

    /// Chunk `rows` logical elements of `row_bytes` bytes each using the
    /// config's nominal chunk size.
    pub fn for_rows(cfg: &PimConfig, rows: u64, row_bytes: u64) -> ChunkPlan {
        ChunkPlan::split(rows, chunk_count(cfg, rows.saturating_mul(row_bytes)))
    }

    pub fn chunks(&self) -> usize {
        self.spans.len()
    }
}

/// How many chunks the config's nominal chunk size suggests for
/// `total_bytes` of per-DPU payload.
pub fn chunk_count(cfg: &PimConfig, total_bytes: u64) -> usize {
    ((total_bytes / cfg.pipeline_chunk_bytes.max(1)) as usize)
        .clamp(1, cfg.pipeline_max_chunks.max(1))
}

/// Byte spans of one per-DPU row split into at most `chunks`
/// near-equal, `quantum`-aligned pieces (the last span absorbs the
/// tail, so the spans always partition `0..row_len` exactly — chunk
/// boundaries never split an element when `quantum` is a multiple of
/// the element size).
pub fn byte_spans(row_len: u64, chunks: usize, quantum: u64) -> Vec<(u64, u64)> {
    let q = quantum.max(1);
    if chunks <= 1 || row_len <= q {
        return vec![(0, row_len)];
    }
    let units = row_len.div_ceil(q);
    let c = (chunks as u64).min(units);
    let base = units / c;
    let extra = units % c;
    let mut spans = Vec::with_capacity(c as usize);
    let mut lo = 0u64;
    for i in 0..c {
        let u = base + u64::from(i < extra);
        let hi = (lo + u * q).min(row_len);
        spans.push((lo, hi));
        lo = hi;
    }
    debug_assert_eq!(lo, row_len);
    spans
}

/// Modeled timing of one pipelined launch at its chosen chunk count.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PipeSchedule {
    /// Chunk count the cost model settled on (1 = monolithic).
    pub chunks: usize,
    /// Input-lane busy seconds (all per-chunk scatter commands).
    pub busy_in_s: f64,
    /// Execution-lane busy seconds (the launch's kernel time).
    pub busy_exec_s: f64,
    /// Output-lane busy seconds (all per-chunk gather commands).
    pub busy_out_s: f64,
    /// Critical-path seconds of the overlapped schedule.
    pub critical_s: f64,
    /// Busy-sum minus critical path: the seconds hidden by overlap.
    pub saved_s: f64,
}

/// Makespan of a three-lane chunk pipeline with `window` staging
/// buffers per direction (2 = double buffering).  `s`/`k`/`g` are the
/// per-chunk input-transfer / execution / output-transfer times; chunk
/// `i` may start its input transfer only once buffer `i − window` has
/// been drained by execution, and execution of chunk `i` needs output
/// buffer `i − window` flushed — the drain/flush semantics of a real
/// double-buffered MRAM staging region.
pub fn makespan(s: &[f64], k: &[f64], g: &[f64], window: usize) -> f64 {
    let c = k.len();
    assert!(c > 0 && s.len() == c && g.len() == c);
    let w = window.max(1);
    let mut in_done = vec![0.0f64; c];
    let mut ex_done = vec![0.0f64; c];
    let mut out_done = vec![0.0f64; c];
    for i in 0..c {
        let prev_in = if i > 0 { in_done[i - 1] } else { 0.0 };
        let in_buf_free = if i >= w { ex_done[i - w] } else { 0.0 };
        in_done[i] = prev_in.max(in_buf_free) + s[i];
        let prev_ex = if i > 0 { ex_done[i - 1] } else { 0.0 };
        let out_buf_free = if i >= w { out_done[i - w] } else { 0.0 };
        ex_done[i] = prev_ex.max(in_done[i]).max(out_buf_free) + k[i];
        let prev_out = if i > 0 { out_done[i - 1] } else { 0.0 };
        out_done[i] = prev_out.max(ex_done[i]) + g[i];
    }
    out_done[c - 1]
}

/// Split `total` bytes into `chunks` near-equal, `align`-aligned parts
/// (byte sum preserved exactly; trailing chunks may be empty when the
/// payload is smaller than the chunk grid).
fn split_aligned(total: u64, chunks: usize, align: u64) -> Vec<u64> {
    if chunks <= 1 {
        return vec![total];
    }
    let a = align.max(1);
    let units = total.div_ceil(a);
    let base = units / chunks as u64;
    let extra = units % chunks as u64;
    let mut out = Vec::with_capacity(chunks);
    let mut used = 0u64;
    for i in 0..chunks as u64 {
        let u = base + u64::from(i < extra);
        let b = (u * a).min(total - used);
        out.push(b);
        used += b;
    }
    debug_assert_eq!(used, total);
    out
}

/// Evaluate one candidate chunk count.
fn eval_candidate(
    cfg: &PimConfig,
    n_dpus: usize,
    in_streams: &[u64],
    out_row_bytes: u64,
    exec_s: f64,
    chunks: usize,
) -> PipeSchedule {
    let splits_in: Vec<Vec<u64>> =
        in_streams.iter().map(|&b| split_aligned(b, chunks, cfg.dma_align)).collect();
    let split_out = split_aligned(out_row_bytes, chunks, cfg.dma_align);
    let mut s = vec![0.0f64; chunks];
    let mut g = vec![0.0f64; chunks];
    for i in 0..chunks {
        for st in &splits_in {
            s[i] += transfer_seconds(cfg, XferKind::Parallel, n_dpus, st[i]);
        }
        g[i] = transfer_seconds(cfg, XferKind::Parallel, n_dpus, split_out[i]);
    }
    let k = vec![exec_s / chunks as f64; chunks];
    let critical = makespan(&s, &k, &g, cfg.pipeline_in_flight);
    let busy_in: f64 = s.iter().sum();
    let busy_out: f64 = g.iter().sum();
    PipeSchedule {
        chunks,
        busy_in_s: busy_in,
        busy_exec_s: exec_s,
        busy_out_s: busy_out,
        critical_s: critical,
        saved_s: (busy_in + exec_s + busy_out - critical).max(0.0),
    }
}

/// Cost model of one pipelined launch: choose the chunk count (from
/// `{1, 2, 4, ...}` up to the config cap) minimizing the overlapped
/// critical path.  `in_streams` holds the per-DPU row bytes of each
/// deferred input scatter (one parallel command per stream per chunk),
/// `out_row_bytes` the per-DPU bytes of a folded-in output gather (0 =
/// none), `exec_s` the launch's total kernel seconds.
///
/// The monolithic candidate (`chunks == 1`, whose critical path is
/// exactly the sum the monolithic request path charges) is always in
/// the search, so the returned schedule never models slower than not
/// pipelining at all.
pub fn schedule(
    cfg: &PimConfig,
    n_dpus: usize,
    in_streams: &[u64],
    out_row_bytes: u64,
    exec_s: f64,
) -> PipeSchedule {
    let total: u64 = in_streams.iter().sum::<u64>() + out_row_bytes;
    let max_c = chunk_count(cfg, total);
    let mut best = eval_candidate(cfg, n_dpus, in_streams, out_row_bytes, exec_s, 1);
    let mut c = 2usize;
    while c <= max_c {
        let cand = eval_candidate(cfg, n_dpus, in_streams, out_row_bytes, exec_s, c);
        if cand.critical_s < best.critical_s {
            best = cand;
        }
        if c == max_c {
            break;
        }
        c = (c * 2).min(max_c);
    }
    best
}

/// Cost model of one pipelined **merge phase** (DESIGN.md §13): the
/// host-rooted collectives end in pull-partials → host combine →
/// push-back, and chunking the accumulator by element range lets chunk
/// `k`'s pull run concurrently with chunk `k−1`'s combine and chunk
/// `k−2`'s push-back — the same three-lane, double-buffered makespan
/// model as [`schedule`], with the host merge in the execution lane
/// and the broadcast push-back in the output lane.
///
/// Unlike [`schedule`], the busy lanes report the **monolithic**
/// transfer charges (what the unpipelined path charges), so the
/// per-direction `Timeline` attribution stays mode-invariant; all
/// chunking overhead and all overlap live in `critical_s` / `saved_s`.
/// The monolithic candidate (`chunks == 1`, critical exactly the
/// serial sum) floors the search, so `saved_s >= 0` always.
pub fn merge_schedule(
    cfg: &PimConfig,
    n_dpus: usize,
    pull_row_bytes: u64,
    merge_s: f64,
    push_bytes: u64,
    push_kind: XferKind,
) -> PipeSchedule {
    let busy_in = transfer_seconds(cfg, XferKind::Parallel, n_dpus, pull_row_bytes);
    let busy_out = transfer_seconds(cfg, push_kind, n_dpus, push_bytes);
    let serial = busy_in + merge_s + busy_out;
    let max_c = chunk_count(cfg, pull_row_bytes + push_bytes);
    let mut best_critical = serial;
    let mut best_chunks = 1usize;
    let mut c = 2usize;
    while c <= max_c {
        let split_in = split_aligned(pull_row_bytes, c, cfg.dma_align);
        let split_out = split_aligned(push_bytes, c, cfg.dma_align);
        let s: Vec<f64> = split_in
            .iter()
            .map(|&b| transfer_seconds(cfg, XferKind::Parallel, n_dpus, b))
            .collect();
        let g: Vec<f64> =
            split_out.iter().map(|&b| transfer_seconds(cfg, push_kind, n_dpus, b)).collect();
        let k = vec![merge_s / c as f64; c];
        let critical = makespan(&s, &k, &g, cfg.pipeline_in_flight);
        if critical < best_critical {
            best_critical = critical;
            best_chunks = c;
        }
        if c == max_c {
            break;
        }
        c = (c * 2).min(max_c);
    }
    PipeSchedule {
        chunks: best_chunks,
        busy_in_s: busy_in,
        busy_exec_s: merge_s,
        busy_out_s: busy_out,
        critical_s: best_critical,
        saved_s: (serial - best_critical).max(0.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> PimConfig {
        PimConfig::upmem(64)
    }

    #[test]
    fn full_pipeline_at_depth_threads() {
        let c = cfg();
        assert_eq!(issue_rate(&c, 11), 1.0);
        assert_eq!(issue_rate(&c, 12), 1.0); // 12 is the paper's default
        assert_eq!(issue_rate(&c, 24), 1.0);
    }

    #[test]
    fn partial_pipeline_is_linear_in_threads() {
        let c = cfg();
        let r1 = issue_rate(&c, 1);
        let r4 = issue_rate(&c, 4);
        let r8 = issue_rate(&c, 8);
        assert!((r4 / r1 - 4.0).abs() < 1e-12);
        assert!((r8 / r4 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn fig11_halving_threads_doubles_time() {
        // Paper §5.4: "the execution time of the 2048-bin histogram (with
        // 4 threads) is roughly twice as high as that of the 1024-bin
        // histogram (with 8 threads)" — same total work, half the rate.
        let c = cfg();
        let slots = 1e9;
        let t8 = cycles(&c, slots, 8);
        let t4 = cycles(&c, slots, 4);
        let t2 = cycles(&c, slots, 2);
        assert!((t4 / t8 - 2.0).abs() < 1e-9);
        assert!((t2 / t4 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn seconds_uses_frequency() {
        let c = cfg();
        let s = seconds(&c, c.freq_hz, 12); // freq_hz slots at full rate
        assert!((s - 1.0).abs() < 1e-9);
    }

    // --- chunk scheduler ---

    #[test]
    fn pipeline_mode_parses() {
        assert_eq!(PipelineMode::parse("off").unwrap(), PipelineMode::Off);
        assert_eq!(PipelineMode::parse("on").unwrap(), PipelineMode::On);
        assert_eq!(PipelineMode::parse("auto").unwrap(), PipelineMode::Auto);
        assert!(PipelineMode::parse("fast").is_err());
        assert_eq!(PipelineMode::Auto.to_string(), "auto");
    }

    #[test]
    fn chunk_plan_spans_partition_rows() {
        for rows in [0u64, 1, 2, 7, 100, 4097] {
            for chunks in [1usize, 2, 3, 8, 200] {
                let p = ChunkPlan::split(rows, chunks);
                let mut next = 0;
                for &(lo, hi) in &p.spans {
                    assert_eq!(lo, next, "contiguous (rows={rows}, chunks={chunks})");
                    assert!(hi >= lo);
                    next = hi;
                }
                assert_eq!(next, rows, "coverage (rows={rows}, chunks={chunks})");
                assert!(p.chunks() <= chunks.max(1));
                if rows > 0 {
                    assert!(p.spans.iter().all(|&(lo, hi)| hi > lo), "no empty spans");
                }
            }
        }
    }

    #[test]
    fn byte_spans_partition_and_respect_quantum() {
        for row_len in [0u64, 8, 24, 100, 131_072, 131_076] {
            for chunks in [1usize, 2, 5, 13, 1000] {
                for quantum in [8u64, 24, 40] {
                    let spans = byte_spans(row_len, chunks, quantum);
                    let mut next = 0;
                    for (i, &(lo, hi)) in spans.iter().enumerate() {
                        assert_eq!(lo, next);
                        assert!(hi >= lo);
                        // Interior boundaries sit on the quantum grid.
                        if i + 1 < spans.len() {
                            assert_eq!(hi % quantum, 0, "row_len={row_len} q={quantum}");
                        }
                        next = hi;
                    }
                    assert_eq!(next, row_len);
                }
            }
        }
    }

    #[test]
    fn makespan_single_chunk_is_serial_sum() {
        let m = makespan(&[3.0], &[2.0], &[1.0], 2);
        assert!((m - 6.0).abs() < 1e-12);
    }

    #[test]
    fn makespan_overlaps_but_never_beats_busiest_lane() {
        let s = [1.0; 8];
        let k = [1.0; 8];
        let g = [1.0; 8];
        let m = makespan(&s, &k, &g, 2);
        // Serial would be 24; a perfect pipeline drains in ~10.
        assert!(m < 24.0, "overlap happens: {m}");
        assert!(m >= 8.0, "cannot beat a fully busy lane: {m}");
        // A single in-flight buffer pipelines less than two.
        assert!(makespan(&s, &k, &g, 1) >= m);
    }

    #[test]
    fn schedule_monolithic_candidate_floors_the_search() {
        let c = cfg();
        // Tiny payload: per-chunk latency can't amortize, C must be 1.
        let tiny = schedule(&c, 64, &[64], 64, 1e-6);
        assert_eq!(tiny.chunks, 1);
        assert!(tiny.saved_s.abs() < 1e-15);

        // Transfer-bound launch with a real kernel: pipelining wins.
        let big = schedule(&c, 64, &[1 << 20, 1 << 20], 1 << 20, 5e-3);
        assert!(big.chunks > 1, "expected chunking, got {}", big.chunks);
        assert!(big.saved_s > 0.0);
        // Never slower than the monolithic serialization.
        let mono = transfer_seconds(&c, XferKind::Parallel, 64, 1 << 20) * 2.0
            + 5e-3
            + transfer_seconds(&c, XferKind::Parallel, 64, 1 << 20);
        assert!(big.critical_s <= mono + 1e-12, "{} vs {mono}", big.critical_s);
        // Lanes carry the full busy time; `saved` accounts the overlap.
        assert!(
            (big.busy_in_s + big.busy_exec_s + big.busy_out_s - big.critical_s - big.saved_s)
                .abs()
                < 1e-12
        );
    }

    #[test]
    fn merge_schedule_overlaps_pull_combine_and_pushback() {
        let c = cfg();
        // 4 MB per-DPU pulls with a hefty combine: chunking must win,
        // never beating the pull lane, never exceeding the serial sum.
        let pull = 4u64 << 20;
        let merge_s = 10e-3;
        let push = 4u64 << 20;
        let m = merge_schedule(&c, 32, pull, merge_s, push, XferKind::Broadcast);
        assert!(m.chunks > 1, "expected chunking, got {}", m.chunks);
        assert!(m.saved_s > 0.0);
        let serial = m.busy_in_s + m.busy_exec_s + m.busy_out_s;
        assert!(m.critical_s <= serial + 1e-15);
        assert!(m.critical_s >= m.busy_in_s, "cannot beat the busiest lane");
        assert!((serial - m.critical_s - m.saved_s).abs() < 1e-12);
        // Busy lanes report the monolithic charges exactly.
        assert_eq!(m.busy_in_s, transfer_seconds(&c, XferKind::Parallel, 32, pull));
        assert_eq!(m.busy_out_s, transfer_seconds(&c, XferKind::Broadcast, 32, push));

        // Tiny payloads: the monolithic candidate floors the search.
        let tiny = merge_schedule(&c, 32, 64, 1e-7, 64, XferKind::Broadcast);
        assert_eq!(tiny.chunks, 1);
        assert_eq!(tiny.saved_s, 0.0);

        // Merge-only phases (no transfers) have nothing to overlap.
        let none = merge_schedule(&c, 32, 0, 5e-3, 0, XferKind::Broadcast);
        assert_eq!(none.chunks, 1);
        assert_eq!(none.saved_s, 0.0);
    }

    #[test]
    fn schedule_handles_empty_lanes() {
        let c = cfg();
        let none = schedule(&c, 64, &[], 0, 1e-3);
        assert_eq!(none.chunks, 1);
        assert_eq!(none.busy_in_s, 0.0);
        assert_eq!(none.busy_out_s, 0.0);
        // Exec + one lane only (scatter∥exec, no gather) still overlaps.
        let in_only = schedule(&c, 64, &[4 << 20], 0, 20e-3);
        assert!(in_only.chunks > 1);
        assert!(in_only.saved_s > 0.0);
    }
}
