//! The DPU's 11-stage fine-grained multithreaded pipeline model.
//!
//! The UPMEM DPU interleaves tasklets in a "revolver" scheme: a given
//! tasklet may have at most one instruction in flight, so it can issue at
//! most once every `pipeline_depth` (11) cycles.  With `T` tasklets the
//! core's issue throughput is `min(T, 11) / 11` instructions per cycle —
//! at least 11 tasklets keep the pipeline full (paper §2, [26, 53]).
//!
//! This single mechanism produces the paper's Fig. 11 behaviour: when the
//! thread-private reduction variant must drop from 12 to 8/4/2 active
//! tasklets (WRAM pressure), execution time grows inversely with the
//! issue rate — "the reduction in the number of active threads causes a
//! linear increase of the execution time".

use super::config::PimConfig;

/// Issue throughput in instructions/cycle for `tasklets` active threads.
pub fn issue_rate(cfg: &PimConfig, tasklets: u32) -> f64 {
    assert!(tasklets >= 1, "at least one tasklet must run");
    let t = tasklets.min(cfg.pipeline_depth);
    t as f64 / cfg.pipeline_depth as f64
}

/// Cycles to retire `slots` issue slots with `tasklets` active threads.
///
/// `slots` is the *total* over all tasklets (the work is pre-partitioned
/// evenly, so per-tasklet imbalance is at most one batch and ignored
/// here; the scheduler accounts for the trailing remainder separately).
pub fn cycles(cfg: &PimConfig, slots: f64, tasklets: u32) -> f64 {
    slots / issue_rate(cfg, tasklets)
}

/// Seconds to retire `slots` issue slots with `tasklets` active threads.
pub fn seconds(cfg: &PimConfig, slots: f64, tasklets: u32) -> f64 {
    cycles(cfg, slots, tasklets) / cfg.freq_hz
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> PimConfig {
        PimConfig::upmem(64)
    }

    #[test]
    fn full_pipeline_at_depth_threads() {
        let c = cfg();
        assert_eq!(issue_rate(&c, 11), 1.0);
        assert_eq!(issue_rate(&c, 12), 1.0); // 12 is the paper's default
        assert_eq!(issue_rate(&c, 24), 1.0);
    }

    #[test]
    fn partial_pipeline_is_linear_in_threads() {
        let c = cfg();
        let r1 = issue_rate(&c, 1);
        let r4 = issue_rate(&c, 4);
        let r8 = issue_rate(&c, 8);
        assert!((r4 / r1 - 4.0).abs() < 1e-12);
        assert!((r8 / r4 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn fig11_halving_threads_doubles_time() {
        // Paper §5.4: "the execution time of the 2048-bin histogram (with
        // 4 threads) is roughly twice as high as that of the 1024-bin
        // histogram (with 8 threads)" — same total work, half the rate.
        let c = cfg();
        let slots = 1e9;
        let t8 = cycles(&c, slots, 8);
        let t4 = cycles(&c, slots, 4);
        let t2 = cycles(&c, slots, 2);
        assert!((t4 / t8 - 2.0).abs() < 1e-9);
        assert!((t2 / t4 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn seconds_uses_frequency() {
        let c = cfg();
        let s = seconds(&c, c.freq_hz, 12); // freq_hz slots at full rate
        assert!((s - 1.0).abs() < 1e-9);
    }
}
