//! `PimMachine`: the assembled simulated PIM system.
//!
//! Owns the per-DPU MRAM banks (functional state), a machine-level MRAM
//! allocator (UPMEM-style same-offset-on-every-bank layout), and the
//! running `Timeline` of modeled costs.  Everything above (the SimplePIM
//! coordinator, the hand-optimized baselines) manipulates PIM state
//! through this type, so functional bytes and modeled seconds stay in
//! sync by construction.

use crate::analysis::XferRecord;
use crate::backend::{ExecBackend, LaunchStatus};
use crate::error::{Error, Result};

use super::config::PimConfig;
use super::faults::{FaultEvent, FaultKind, FaultSession, FaultSpec, RecoveryPolicy};
use super::memory::{MramAllocator, MramBank};
use super::xfer::{transfer_seconds, XferKind};

/// Accumulated modeled time, split by phase (the split the paper's
/// figures discuss: kernel vs communication).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Timeline {
    /// Host -> PIM transfer seconds (scatter/broadcast).
    pub host_to_pim_s: f64,
    /// PIM -> host transfer seconds (gather).
    pub pim_to_host_s: f64,
    /// PIM kernel seconds (max over DPUs per launch, summed over
    /// launches).
    pub kernel_s: f64,
    /// Host-side merge seconds (the "host version of acc_func" work).
    pub host_merge_s: f64,
    /// Fixed kernel-launch overheads.
    pub launch_s: f64,
    /// Number of kernel launches.
    pub launches: u64,
    /// Total bytes moved host->PIM.
    pub bytes_h2p: u64,
    /// Total bytes moved PIM->host.
    pub bytes_p2h: u64,
    /// Seconds hidden by pipelined launches: overlapped chunk transfers
    /// charged as `max(xfer, exec)` per chunk instead of their sum.
    /// The per-phase lanes keep their full busy time (so bytes and
    /// per-direction attribution stay comparable across modes); this
    /// lane subtracts the overlap in [`Timeline::total_s`].
    pub overlap_saved_s: f64,
    /// Kernel launches executed as chunked, double-buffered pipelines.
    pub pipelined_launches: u64,
    /// Total chunks across pipelined launches.
    pub pipeline_chunks: u64,
    /// The merge engine's lane (DESIGN.md §13): host-side combine
    /// seconds of collectives and reduction finalizations, charged per
    /// the executing backend's merge strategy (serial fold vs
    /// ⌈log₂ n⌉-depth tree).  `host_merge_s` keeps the other host-root
    /// work (e.g. the scan base pass).
    pub merge_s: f64,
    /// What the serial reference fold would have charged for the same
    /// merges (`--explain` shows the win as merge_serial_s / merge_s).
    pub merge_serial_s: f64,
    /// Elementwise combine operations performed by those merges —
    /// `(n_dpus − 1) × len` per reduce, strategy-invariant.
    pub merge_elems: u64,
    /// Tree levels executed (0 for the serial fold).
    pub merge_levels: u64,
    /// Merge-engine invocations.
    pub merges: u64,
    /// Merges whose pull ∥ combine ∥ push-back phases were overlapped
    /// by the chunk pipeline.
    pub pipelined_merges: u64,
    /// Seconds hidden by pipelined merge phases — kept separate from
    /// `overlap_saved_s` (which stays kernel-launch-only and
    /// backend-invariant) because merge overlap scales with the
    /// backend's merge strategy.  Subtracted in [`Timeline::total_s`].
    pub merge_overlap_saved_s: f64,
    /// Total chunks across pipelined merge phases.
    pub merge_chunks: u64,
    /// Cross-tenant broadcast dedup (DESIGN.md §16): transfer seconds
    /// this lane did *not* pay because an identical read-only context
    /// shipped to the same partition set was charged once across the
    /// batch instead of once per job.  The `host_to_pim_s` lane keeps
    /// its full per-job charge (per-direction attribution stays
    /// comparable across sharing modes, like `overlap_saved_s`); this
    /// lane subtracts the dedup in [`Timeline::total_s`].  Always 0
    /// outside the job scheduler's shared-cache mode.
    pub bcast_dedup_saved_s: f64,
    /// Broadcast ships elided by cross-tenant dedup.
    pub bcast_dedups: u64,
    /// Gang co-launch (DESIGN.md §16): launch-overhead seconds saved
    /// because compatible same-kernel jobs on rank-adjacent partitions
    /// were batched into one gang launch command.  `launch_s` keeps
    /// the full per-job overhead; subtracted in [`Timeline::total_s`].
    /// Always 0 outside the job scheduler's shared-cache mode.
    pub colaunch_saved_s: f64,
    /// 1 when this timeline's job joined a co-launch gang, else 0
    /// (summing across a batch counts the gang members).
    pub colaunched: u64,
    /// Fault-recovery retry lane (DESIGN.md §18): modeled seconds spent
    /// reissuing faulted launches/transfers plus their exponential
    /// backoff waits.  Its own lane — the phase lanes above keep only
    /// the successful attempt, so a fault-free run and a recovered run
    /// have identical phase charges and differ exactly by this lane.
    /// Added in [`Timeline::total_s`].  Always 0 with faults off.
    pub retry_s: f64,
    /// Recovery reissues performed (one per absorbed fault).
    pub retries: u64,
    /// Faults injected into this lane's operations (absorbed + the one
    /// that dead-lettered, when recovery ran out of budget).
    pub faults_injected: u64,
}

impl Timeline {
    /// End-to-end modeled seconds.
    pub fn total_s(&self) -> f64 {
        self.host_to_pim_s + self.pim_to_host_s + self.kernel_s + self.host_merge_s
            + self.merge_s
            + self.launch_s
            + self.retry_s
            - self.overlap_saved_s
            - self.merge_overlap_saved_s
            - self.bcast_dedup_saved_s
            - self.colaunch_saved_s
    }

    /// Communication-only seconds (both directions + merges).
    pub fn comm_s(&self) -> f64 {
        self.host_to_pim_s + self.pim_to_host_s + self.host_merge_s + self.merge_s
    }
}

/// A disjoint, contiguous slice of a machine's DPUs, virtualized as an
/// independent device (the multi-tenant scheduler's unit of tenancy,
/// DESIGN.md §14).  The set carries its own [`PimConfig`] view: the
/// same per-DPU constants as the parent, a proportional share of the
/// parent's host<->PIM bus bandwidth and host merge threads, and its
/// own `n_dpus` — so a [`PimMachine`] built from it accounts a
/// per-partition [`Timeline`] lane that composes into the device
/// makespan without double-counting shared resources.
#[derive(Debug, Clone)]
pub struct DpuSet {
    /// First DPU of the parent machine this set covers.
    pub first_dpu: usize,
    /// DPUs in the set.
    pub n_dpus: usize,
    cfg: PimConfig,
}

impl DpuSet {
    /// Split a machine into `parts` equal, disjoint, contiguous DPU
    /// sets.  Partition counts that do not divide the DPU count are an
    /// explicit [`Error::Config`] (unequal partitions would make a
    /// job's modeled time depend on which partition admitted it, so
    /// per-job charges could no longer be scheduler-mode-invariant).
    pub fn split(parent: &PimConfig, parts: usize) -> Result<Vec<DpuSet>> {
        if parts == 0 {
            return Err(Error::Config(
                "partition count must be >= 1, got 0 (a device with no partitions \
                 could never admit a job)"
                    .into(),
            ));
        }
        if parts > parent.n_dpus {
            return Err(Error::Config(format!(
                "cannot split {} DPUs into {parts} partitions (more partitions than DPUs)",
                parent.n_dpus
            )));
        }
        if parent.n_dpus % parts != 0 {
            return Err(Error::Config(format!(
                "{} DPUs do not split evenly into {parts} partitions; choose a divisor \
                 of the DPU count (unequal partitions would make per-job modeled time \
                 depend on the admission assignment)",
                parent.n_dpus
            )));
        }
        let k = parent.n_dpus / parts;
        // With an explicit channel→rank→DPU tree (DESIGN.md §15), cuts
        // must land on rank boundaries: a partition straddling a rank
        // would share one physical transfer engine with its neighbor,
        // so the per-partition lanes could no longer compose into the
        // device makespan without double-counting that engine.
        if parent.explicit_topology() && k % parent.rank_dpus() != 0 {
            return Err(Error::Config(format!(
                "partition of {k} DPUs straddles a rank boundary ({} DPUs/rank); \
                 choose a partition count whose shares cover whole ranks",
                parent.rank_dpus()
            )));
        }
        // Each partition gets a proportional share of the parent's
        // aggregate parallel-transfer bandwidth and host merge threads:
        // concurrent tenants contend for the DIMM bus and the host CPU,
        // so P partitions moving data at once must never model more
        // aggregate bandwidth than the whole machine had.  Only the
        // *ceiling* is scaled — per-rank bandwidth keeps the parent's
        // value, so a partial-rank transfer models exactly as it would
        // on the whole machine and `split(cfg, 1)` is the identity even
        // when the parent's ceiling binds (many-rank configs).
        let share = parent.parallel_bw() * k as f64 / parent.n_dpus as f64;
        let mut cfg = parent.clone();
        cfg.n_dpus = k;
        cfg.xfer_bw_ceiling = share;
        // Floor of one host thread per partition: when a machine has
        // fewer host threads than partitions the model mildly
        // oversubscribes the host CPU (P threads modeled vs
        // `host_threads` real) — a deliberate simplification; with the
        // default 32-thread host it never triggers below 33 partitions.
        cfg.host_threads = ((parent.host_threads * k) / parent.n_dpus).max(1);
        // The partition inherits its slice of the topology tree: the
        // ranks it covers, grouped back into whole channels when the
        // cut lands on a channel boundary (so `split(cfg, 1)` is the
        // identity), otherwise as a single-channel run of ranks.
        if parent.explicit_topology() {
            let ranks_in_part = k / parent.rank_dpus();
            if ranks_in_part % parent.ranks_per_channel == 0 {
                cfg.n_channels = ranks_in_part / parent.ranks_per_channel;
                cfg.ranks_per_channel = parent.ranks_per_channel;
            } else {
                cfg.n_channels = 1;
                cfg.ranks_per_channel = ranks_in_part;
            }
        }
        Ok((0..parts)
            .map(|i| DpuSet { first_dpu: i * k, n_dpus: k, cfg: cfg.clone() })
            .collect())
    }

    /// Merge adjacent partitions back into one bigger set — the other
    /// half of dynamic partition resizing (DESIGN.md §17): the online
    /// scheduler folds idle neighbors into a big job's set and splits
    /// them back under load.  `sets` must be non-empty and contiguous
    /// in DPU order; on a machine with an explicit topology the merged
    /// run must also cover whole ranks (the same double-counting
    /// argument as [`Self::split`] — a merged set sharing a rank's
    /// transfer engine with an outside partition could not be charged
    /// as an independent lane).  The merged view gets the same
    /// proportional bus/host share its DPU count would get from
    /// `split`, so `merge(split(cfg, p)) == split(cfg, 1)[0]` and a
    /// job's modeled time depends only on how many DPUs it ran on,
    /// never on the resize path that produced them.
    pub fn merge(parent: &PimConfig, sets: &[DpuSet]) -> Result<DpuSet> {
        let (first, rest) = sets.split_first().ok_or_else(|| {
            Error::Config(
                "cannot merge zero partitions (a set with no DPUs could never run a job)".into(),
            )
        })?;
        let mut end = first.first_dpu + first.n_dpus;
        for s in rest {
            if s.first_dpu != end {
                return Err(Error::Config(format!(
                    "cannot merge non-adjacent partitions (gap between DPU {end} and \
                     DPU {}); dynamic resizing only folds contiguous neighbors",
                    s.first_dpu
                )));
            }
            end += s.n_dpus;
        }
        let k = end - first.first_dpu;
        if first.first_dpu + k > parent.n_dpus {
            return Err(Error::Config(format!(
                "merged partition [{}, {}) exceeds the machine's {} DPUs",
                first.first_dpu, end, parent.n_dpus
            )));
        }
        if parent.explicit_topology()
            && (first.first_dpu % parent.rank_dpus() != 0 || k % parent.rank_dpus() != 0)
        {
            return Err(Error::Config(format!(
                "merged partition of {k} DPUs at DPU {} straddles a rank boundary \
                 ({} DPUs/rank); merge whole ranks only",
                first.first_dpu,
                parent.rank_dpus()
            )));
        }
        // Identical share math to `split`: the merged set's bandwidth
        // ceiling and host threads are the proportional share its DPU
        // count would get, independent of how many sets folded into it.
        let share = parent.parallel_bw() * k as f64 / parent.n_dpus as f64;
        let mut cfg = parent.clone();
        cfg.n_dpus = k;
        cfg.xfer_bw_ceiling = share;
        cfg.host_threads = ((parent.host_threads * k) / parent.n_dpus).max(1);
        if parent.explicit_topology() {
            let ranks_in_part = k / parent.rank_dpus();
            if ranks_in_part % parent.ranks_per_channel == 0 {
                cfg.n_channels = ranks_in_part / parent.ranks_per_channel;
                cfg.ranks_per_channel = parent.ranks_per_channel;
            } else {
                cfg.n_channels = 1;
                cfg.ranks_per_channel = ranks_in_part;
            }
        }
        Ok(DpuSet { first_dpu: first.first_dpu, n_dpus: k, cfg })
    }

    /// The partition-local machine view (parent constants, partition
    /// DPU count, proportional bus/host share).
    pub fn cfg(&self) -> &PimConfig {
        &self.cfg
    }

    /// Build an independent simulated machine over this set, with its
    /// own banks and its own per-partition `Timeline` lane.
    pub fn machine(&self) -> PimMachine {
        PimMachine::new(self.cfg.clone())
    }
}

/// Cap on the sanitizer's transfer log.  Beyond this the machine stops
/// recording (a sound truncation: the audit over the retained prefix
/// never sees a read whose matching write was dropped, because drops
/// only ever discard *later* records).
const MAX_XFER_RECORDS: usize = 4096;

/// The simulated machine.
pub struct PimMachine {
    pub cfg: PimConfig,
    banks: Vec<MramBank>,
    allocator: MramAllocator,
    timeline: Timeline,
    /// Installed fault-injection stream + recovery policy (DESIGN.md
    /// §18).  `None` (the default) keeps every timed path exactly as
    /// it was: no draws, no checksums, no extra lanes.
    faults: Option<(FaultSession, RecoveryPolicy)>,
    /// Sanitizer mode (DESIGN.md §19): when armed, every timed row
    /// transfer appends an [`XferRecord`] with an FNV digest of the
    /// rows it moved, so the analyzer's static verdicts can be
    /// cross-checked against what the device actually saw.  Off by
    /// default — recording never perturbs bytes or modeled seconds,
    /// but it is debug instrumentation, not part of `--analyze`.
    sanitize: bool,
    xfer_log: Vec<XferRecord>,
    /// Records discarded once the log hit [`MAX_XFER_RECORDS`].
    xfer_dropped: u64,
}

impl PimMachine {
    pub fn new(cfg: PimConfig) -> Self {
        let banks = (0..cfg.n_dpus).map(|_| MramBank::new(cfg.mram_bytes)).collect();
        let allocator = MramAllocator::new(cfg.mram_bytes, cfg.dma_align);
        PimMachine {
            cfg,
            banks,
            allocator,
            timeline: Timeline::default(),
            faults: None,
            sanitize: false,
            xfer_log: Vec::new(),
            xfer_dropped: 0,
        }
    }

    /// Arm fault injection on this lane: fork the plan's seeded stream
    /// with `salt` (the job's submission index, so racing batch workers
    /// cannot perturb each other's draws) under `policy`.
    pub fn install_faults(&mut self, spec: &FaultSpec, salt: u64, policy: RecoveryPolicy) {
        self.faults = Some((FaultSession::new(spec, salt), policy));
    }

    /// Faults injected into this lane so far, in injection order (the
    /// dead-letter message renders the same history).
    pub fn fault_events(&self) -> &[FaultEvent] {
        self.faults.as_ref().map(|(s, _)| s.events.as_slice()).unwrap_or(&[])
    }

    /// Arm or disarm the transfer sanitizer (DESIGN.md §19).  Arming
    /// clears any previous log so a report covers one armed window.
    pub fn set_sanitizer(&mut self, on: bool) {
        if on && !self.sanitize {
            self.xfer_log.clear();
            self.xfer_dropped = 0;
        }
        self.sanitize = on;
    }

    /// Whether the transfer sanitizer is currently recording.
    pub fn sanitizer_enabled(&self) -> bool {
        self.sanitize
    }

    /// Recorded transfers, in device order (empty when disarmed).
    pub fn xfer_log(&self) -> &[XferRecord] {
        &self.xfer_log
    }

    /// Append one sanitizer record: digest the `row_len` bytes at
    /// `addr` on every bank *as the device holds them now* — after a
    /// write, before a read — so a static verdict of "this region is
    /// what was shipped" can be replayed against real bank state.  A
    /// bank too small for the row skips recording (the transfer itself
    /// already failed loudly); a full log drops silently but counts.
    fn sanitize_record(&mut self, write: bool, addr: u64, row_len: u64, what: &'static str) {
        if !self.sanitize {
            return;
        }
        if self.xfer_log.len() >= MAX_XFER_RECORDS {
            self.xfer_dropped += 1;
            return;
        }
        let mut rows = Vec::with_capacity(self.banks.len());
        for bank in &self.banks {
            match bank.read(addr, row_len) {
                Ok(bytes) => rows.push(bytes.to_vec()),
                Err(_) => return,
            }
        }
        let digest = super::faults::checksum_rows(&rows);
        self.xfer_log.push(XferRecord { write, addr, row_len, digest, what });
    }

    pub fn n_dpus(&self) -> usize {
        self.banks.len()
    }

    /// Partition this machine's DPU range into `parts` equal
    /// [`DpuSet`] views (the scheduler's tenancy units, DESIGN.md §14).
    pub fn partition(&self, parts: usize) -> Result<Vec<DpuSet>> {
        DpuSet::split(&self.cfg, parts)
    }

    pub fn timeline(&self) -> Timeline {
        self.timeline
    }

    /// Reset the modeled timeline (keeps functional state).
    pub fn reset_timeline(&mut self) {
        self.timeline = Timeline::default();
    }

    /// Allocate `bytes` at the same offset on every bank.
    pub fn alloc(&mut self, bytes: u64) -> Result<u64> {
        self.allocator.alloc(bytes)
    }

    /// Free a machine-level allocation.
    pub fn free(&mut self, addr: u64) -> Result<()> {
        self.allocator.free(addr)
    }

    /// Bytes allocated per bank.
    pub fn mram_used(&self) -> u64 {
        self.allocator.used()
    }

    fn bank(&self, dpu: usize) -> Result<&MramBank> {
        self.banks
            .get(dpu)
            .ok_or_else(|| Error::msg(format!("DPU {dpu} out of range ({})", self.banks.len())))
    }

    fn bank_mut(&mut self, dpu: usize) -> Result<&mut MramBank> {
        let n = self.banks.len();
        self.banks
            .get_mut(dpu)
            .ok_or_else(|| Error::msg(format!("DPU {dpu} out of range ({n})")))
    }

    // ---------------------------------------------------------------
    // Functional state (no timing): used by the coordinator internals.
    // ---------------------------------------------------------------

    /// Raw read from one DPU's bank.
    pub fn read_bytes(&self, dpu: usize, addr: u64, len: u64) -> Result<Vec<u8>> {
        Ok(self.bank(dpu)?.read(addr, len)?.to_vec())
    }

    /// Raw write to one DPU's bank.
    pub fn write_bytes(&mut self, dpu: usize, addr: u64, bytes: &[u8]) -> Result<()> {
        self.bank_mut(dpu)?.write(addr, bytes)
    }

    // ---------------------------------------------------------------
    // Backend-sharded row I/O.  The `*_with` methods route the per-DPU
    // marshalling loops through an execution backend, which may shard
    // the bank array across rank workers; the timed variants charge
    // exactly what their loop-based counterparts charge, so modeled
    // seconds stay backend-invariant by construction.
    // ---------------------------------------------------------------

    /// Functional sharded write (no timing): one `row_len`-byte row per
    /// bank at `addr`, marshalled on demand by `fill(dpu, buf)` into a
    /// zeroed staging buffer.  Used to materialize deferred map outputs
    /// (modeled as kernel work, not a host transfer).
    pub fn write_rows_with(
        &mut self,
        addr: u64,
        row_len: usize,
        exec: &dyn ExecBackend,
        fill: &(dyn Fn(usize, &mut [u8]) + Sync),
    ) -> Result<()> {
        exec.write_rows(&mut self.banks, addr, row_len, fill)?;
        self.sanitize_record(true, addr, row_len as u64, "sharded row write");
        Ok(())
    }

    /// Timed parallel push with on-demand row marshalling: functionally
    /// [`Self::write_rows_with`], charged exactly like
    /// [`Self::push_parallel`] with `n_dpus` equal buffers of `row_len`
    /// bytes (the UPMEM parallel-command rule).
    pub fn push_rows_with(
        &mut self,
        addr: u64,
        row_len: usize,
        exec: &dyn ExecBackend,
        fill: &(dyn Fn(usize, &mut [u8]) + Sync),
    ) -> Result<()> {
        exec.write_rows(&mut self.banks, addr, row_len, fill)?;
        self.sanitize_record(true, addr, row_len as u64, "sharded row scatter");
        let n = self.banks.len();
        let t = transfer_seconds(&self.cfg, XferKind::Parallel, n, row_len as u64);
        self.guard_transfer(t, None, "sharded row scatter")?;
        self.timeline.host_to_pim_s += t;
        self.timeline.bytes_h2p += (n * row_len) as u64;
        Ok(())
    }

    /// Functional sharded read (no timing): `take(dpu)` bytes at `addr`
    /// from every bank, unmarshalled into i32 words per DPU.
    pub fn read_rows_with(
        &self,
        addr: u64,
        exec: &dyn ExecBackend,
        take: &(dyn Fn(usize) -> u64 + Sync),
    ) -> Result<Vec<Vec<i32>>> {
        exec.read_rows(&self.banks, addr, take)
    }

    /// Timed parallel pull with sharded unmarshalling: reads only the
    /// `take(dpu)` live bytes per bank but charges the equal-buffer
    /// parallel transfer of `row_len` bytes per DPU, exactly like
    /// [`Self::pull_parallel`].
    pub fn pull_rows_with(
        &mut self,
        addr: u64,
        row_len: u64,
        exec: &dyn ExecBackend,
        take: &(dyn Fn(usize) -> u64 + Sync),
    ) -> Result<Vec<Vec<i32>>> {
        let out = exec.read_rows(&self.banks, addr, take)?;
        self.sanitize_record(false, addr, row_len, "sharded row gather");
        let n = self.banks.len();
        let t = transfer_seconds(&self.cfg, XferKind::Parallel, n, row_len);
        self.guard_transfer(t, None, "sharded row gather")?;
        self.timeline.pim_to_host_s += t;
        self.timeline.bytes_p2h += n as u64 * row_len;
        Ok(out)
    }

    // ---------------------------------------------------------------
    // Pipelined transfer engine (DESIGN.md §12): chunked row I/O
    // reference implementations plus lane charges computed by the
    // chunk scheduler.  The chunked variants are the *functional proof*
    // that chunk-boundary staging cannot change bytes: the property
    // suite (rust/tests/pipeline.rs) pins them to the backend-sharded
    // monolithic paths over ragged/empty/non-8-aligned shapes, which
    // is what lets the production scatter/gather stay on the sharded
    // `write_rows_with`/`read_rows_with` even in pipelined mode.
    // Timing for pipelined launches is charged by the coordinator from
    // `pipeline::schedule`, not here.
    // ---------------------------------------------------------------

    /// Functional chunked row write (no timing): `spans` partition each
    /// DPU's `row_len`-byte row; every span is written as its own bank
    /// store, the staging order of a chunked double-buffered scatter.
    /// Each row is marshalled once; the cross-DPU interleaving of
    /// chunks is a modeled concern, not a functional one.  Reference
    /// implementation for the chunked-staging equivalence proof — the
    /// production pipelined scatter keeps the backend-sharded write.
    pub fn write_rows_chunked(
        &mut self,
        addr: u64,
        row_len: usize,
        spans: &[(u64, u64)],
        fill: &(dyn Fn(usize, &mut [u8]) + Sync),
    ) -> Result<()> {
        let mut buf = vec![0u8; row_len];
        for (dpu, bank) in self.banks.iter_mut().enumerate() {
            buf.fill(0);
            fill(dpu, &mut buf);
            for &(lo, hi) in spans {
                bank.write(addr + lo, &buf[lo as usize..hi as usize])?;
            }
        }
        Ok(())
    }

    /// Functional chunked row read (no timing): read each span of every
    /// bank's row, keep the `take(dpu)` live bytes, and unmarshal into
    /// i32 words (byte counts must be 4-aligned, as in
    /// [`Self::read_rows_with`]).  Spans must be ascending.  Reference
    /// implementation, like [`Self::write_rows_chunked`]: the folded
    /// pipelined gather reads through the sharded `read_rows_with`.
    pub fn read_rows_chunked(
        &self,
        addr: u64,
        spans: &[(u64, u64)],
        take: &(dyn Fn(usize) -> u64 + Sync),
    ) -> Result<Vec<Vec<i32>>> {
        let mut out = Vec::with_capacity(self.banks.len());
        for (dpu, bank) in self.banks.iter().enumerate() {
            let live = take(dpu);
            let mut bytes = Vec::with_capacity(live as usize);
            for &(lo, hi) in spans {
                if lo >= live {
                    break;
                }
                let end = hi.min(live);
                bytes.extend_from_slice(bank.read(addr + lo, end - lo)?);
            }
            out.push(crate::coordinator::comm::bytes_to_words(&bytes));
        }
        Ok(out)
    }

    /// Borrow every bank's live row at `addr` as i32 word views and
    /// hand them to `f` — the merge engine's zero-copy pull side
    /// (DESIGN.md §13).  `take(dpu)` bytes per bank must be 4-aligned;
    /// rows whose bank bytes happen to be misaligned for an in-place
    /// view (or any row on a big-endian host) are staged through a
    /// fresh word buffer instead, so results never depend on allocator
    /// luck.  Functional only: the timed pull is charged separately.
    pub fn with_row_words<R>(
        &self,
        addr: u64,
        take: &dyn Fn(usize) -> u64,
        f: impl FnOnce(&[&[i32]]) -> R,
    ) -> Result<R> {
        use crate::coordinator::comm::{bytes_as_words, bytes_to_words};
        let mut raw: Vec<&[u8]> = Vec::with_capacity(self.banks.len());
        for (dpu, bank) in self.banks.iter().enumerate() {
            raw.push(bank.read(addr, take(dpu))?);
        }
        let staged: Vec<Option<Vec<i32>>> = raw
            .iter()
            .map(|b| if bytes_as_words(b).is_some() { None } else { Some(bytes_to_words(b)) })
            .collect();
        let views: Vec<&[i32]> = raw
            .iter()
            .zip(&staged)
            .map(|(b, s)| match s {
                Some(v) => v.as_slice(),
                None => bytes_as_words(b).expect("alignment checked above"),
            })
            .collect();
        Ok(f(&views))
    }

    /// Charge host->PIM transfer seconds computed elsewhere (the chunk
    /// scheduler's busy time, or a deferred scatter's monolithic flush)
    /// without touching functional state.
    pub fn charge_h2p(&mut self, seconds: f64, bytes: u64) {
        self.timeline.host_to_pim_s += seconds;
        self.timeline.bytes_h2p += bytes;
    }

    /// Charge PIM->host transfer seconds computed elsewhere.
    pub fn charge_p2h(&mut self, seconds: f64, bytes: u64) {
        self.timeline.pim_to_host_s += seconds;
        self.timeline.bytes_p2h += bytes;
    }

    /// Record one pipelined launch: `saved_s` seconds of transfer time
    /// hidden behind execution across `chunks` chunks (subtracted from
    /// the phase-lane sum in [`Timeline::total_s`]).
    pub fn charge_overlap(&mut self, saved_s: f64, chunks: u64) {
        self.timeline.overlap_saved_s += saved_s;
        self.timeline.pipelined_launches += 1;
        self.timeline.pipeline_chunks += chunks;
    }

    /// Charge one merge-engine combine to the merge lane (DESIGN.md
    /// §13): `seconds` per the executing strategy, `serial_s` what the
    /// serial reference fold would have cost, `elems` the
    /// strategy-invariant combine count, `levels` the tree depth (0
    /// for the serial fold).
    pub fn charge_merge(&mut self, seconds: f64, serial_s: f64, elems: u64, levels: u64) {
        self.timeline.merge_s += seconds;
        self.timeline.merge_serial_s += serial_s;
        self.timeline.merge_elems += elems;
        self.timeline.merge_levels += levels;
        self.timeline.merges += 1;
    }

    /// Record one pipelined merge phase: pull chunk `k` ∥ combine
    /// chunk `k−1` ∥ push-back chunk `k−2` hid `saved_s` seconds
    /// across `chunks` chunks (its own lane, so the kernel-launch
    /// overlap lane stays backend-invariant).
    pub fn charge_merge_overlap(&mut self, saved_s: f64, chunks: u64) {
        self.timeline.merge_overlap_saved_s += saved_s;
        self.timeline.pipelined_merges += 1;
        self.timeline.merge_chunks += chunks;
    }

    // ---------------------------------------------------------------
    // Timed host<->PIM operations (the communication interface's
    // engine room).
    // ---------------------------------------------------------------

    /// Parallel push: write `per_dpu[i]` to DPU `i` at `addr`; all
    /// buffers must be the same length (UPMEM parallel-command rule).
    pub fn push_parallel(&mut self, addr: u64, per_dpu: &[Vec<u8>]) -> Result<()> {
        let Some(first) = per_dpu.first() else { return Ok(()) };
        let len = first.len();
        if per_dpu.iter().any(|b| b.len() != len) {
            return Err(Error::Alignment(
                "parallel transfer requires equal-sized buffers on all DPUs".into(),
            ));
        }
        for (dpu, buf) in per_dpu.iter().enumerate() {
            self.bank_mut(dpu)?.write(addr, buf)?;
        }
        self.sanitize_record(true, addr, len as u64, "parallel push");
        let t = transfer_seconds(&self.cfg, XferKind::Parallel, per_dpu.len(), len as u64);
        self.guard_transfer(t, Some(first), "parallel push")?;
        self.timeline.host_to_pim_s += t;
        self.timeline.bytes_h2p += (per_dpu.len() * len) as u64;
        Ok(())
    }

    /// Parallel pull: read `len` bytes at `addr` from the first
    /// `n_dpus` DPUs.
    pub fn pull_parallel(&mut self, addr: u64, len: u64, n_dpus: usize) -> Result<Vec<Vec<u8>>> {
        let mut out = Vec::with_capacity(n_dpus);
        for dpu in 0..n_dpus {
            out.push(self.bank(dpu)?.read(addr, len)?.to_vec());
        }
        self.sanitize_record(false, addr, len, "parallel pull");
        let t = transfer_seconds(&self.cfg, XferKind::Parallel, n_dpus, len);
        self.guard_transfer(t, out.first().map(|b| b.as_slice()), "parallel pull")?;
        self.timeline.pim_to_host_s += t;
        self.timeline.bytes_p2h += n_dpus as u64 * len;
        Ok(out)
    }

    /// Broadcast: same bytes to every DPU at `addr`.
    pub fn push_broadcast(&mut self, addr: u64, bytes: &[u8]) -> Result<()> {
        for dpu in 0..self.n_dpus() {
            self.bank_mut(dpu)?.write(addr, bytes)?;
        }
        self.sanitize_record(true, addr, bytes.len() as u64, "broadcast push");
        let t =
            transfer_seconds(&self.cfg, XferKind::Broadcast, self.n_dpus(), bytes.len() as u64);
        self.guard_transfer(t, Some(bytes), "broadcast push")?;
        self.timeline.host_to_pim_s += t;
        self.timeline.bytes_h2p += bytes.len() as u64; // counted once
        Ok(())
    }

    /// Serial pull from a single DPU (used by debugging paths and the
    /// baseline codes that didn't arrange data for parallel commands).
    pub fn pull_serial(&mut self, dpu: usize, addr: u64, len: u64) -> Result<Vec<u8>> {
        let out = self.bank(dpu)?.read(addr, len)?.to_vec();
        let t = transfer_seconds(&self.cfg, XferKind::Serial, 1, len);
        self.guard_transfer(t, Some(&out), "serial pull")?;
        self.timeline.pim_to_host_s += t;
        self.timeline.bytes_p2h += len;
        Ok(out)
    }

    // ---------------------------------------------------------------
    // Fault injection + recovery (DESIGN.md §18).  Both guards follow
    // the same shape: with no session installed they are a single
    // branch (faults-off stays bit- and timeline-identical); with one,
    // each injected fault costs a reissue — the wasted attempt plus an
    // exponentially growing backoff, charged to the retry lane — until
    // the draw comes up clean or the budget dead-letters the op.
    // Functional bank state is never corrupted: the model detects the
    // fault (checksum mismatch / status word) and resends the original
    // payload, which is why recovered runs are bit-identical to
    // fault-free runs by construction.
    // ---------------------------------------------------------------

    /// Fault hook around one timed transfer whose successful attempt
    /// costs `t_s` modeled seconds.  `payload` feeds the FNV checksum
    /// check when the marshalled bytes are at hand (push/pull buffers);
    /// row-fill paths pass `None` and detect by command timeout alone.
    fn guard_transfer(&mut self, t_s: f64, payload: Option<&[u8]>, what: &str) -> Result<()> {
        let Some((mut session, policy)) = self.faults.take() else { return Ok(()) };
        let n_ranks = self.cfg.n_ranks();
        let mut attempt: u32 = 0;
        while let Some((kind, rank)) = session.draw_transfer(n_ranks) {
            let detected = match (kind, payload) {
                (FaultKind::BitFlip, Some(p)) => session.bitflip_detected(p),
                _ => true, // stalls and draw-only sites detect by timeout
            };
            assert!(detected, "a single-bit flip cannot evade the FNV checksum");
            attempt += 1;
            self.timeline.faults_injected += 1;
            session.record(kind, rank, self.timeline.total_s(), attempt);
            if attempt > policy.retry_budget {
                let msg = format!(
                    "{what} on rank {rank} ({}) exhausted its retry budget of {}: \
                     dead-letter (history: {})",
                    self.cfg.topology_desc(),
                    policy.retry_budget,
                    session.history()
                );
                self.faults = Some((session, policy));
                return Err(Error::Fault(msg));
            }
            let backoff = policy.backoff_base_s * (1u64 << (attempt - 1).min(32)) as f64;
            self.timeline.retry_s += t_s + backoff;
            self.timeline.retries += 1;
        }
        self.faults = Some((session, policy));
        Ok(())
    }

    // ---------------------------------------------------------------
    // Timed kernel accounting.
    // ---------------------------------------------------------------

    /// Charge one kernel launch whose slowest DPU takes `max_dpu_s`.
    pub fn charge_kernel(&mut self, max_dpu_s: f64) {
        self.timeline.kernel_s += max_dpu_s;
        self.timeline.launch_s += self.cfg.launch_latency_s;
        self.timeline.launches += 1;
    }

    /// [`Self::charge_kernel`] behind the launch fault guard: consult
    /// the executing backend's status word for every injected launch
    /// failure, reissue (wasted launch overhead + backoff on the retry
    /// lane) until the status comes back [`LaunchStatus::Ok`], then
    /// charge the successful launch normally.  The launch sites route
    /// through here so fault sequences are backend-invariant: every
    /// backend surfaces the same status word for the same draw.
    pub fn guarded_launch(&mut self, max_dpu_s: f64, backend: &dyn ExecBackend) -> Result<()> {
        if let Some((mut session, policy)) = self.faults.take() {
            let n_ranks = self.cfg.n_ranks();
            let mut attempt: u32 = 0;
            while let Some((rank, code)) = session.draw_launch(n_ranks) {
                let status = backend.launch_status(Some(code));
                assert!(
                    status != LaunchStatus::Ok,
                    "an injected fault code must surface as a non-OK launch status"
                );
                attempt += 1;
                self.timeline.faults_injected += 1;
                session.record(FaultKind::LaunchFail, rank, self.timeline.total_s(), attempt);
                if attempt > policy.retry_budget {
                    let msg = format!(
                        "kernel launch on rank {rank} ({}) exhausted its retry budget \
                         of {}: dead-letter (history: {})",
                        self.cfg.topology_desc(),
                        policy.retry_budget,
                        session.history()
                    );
                    self.faults = Some((session, policy));
                    return Err(Error::Fault(msg));
                }
                // A failed launch wastes its fixed overhead, not kernel
                // time — the DPUs never ran the body.
                let backoff = policy.backoff_base_s * (1u64 << (attempt - 1).min(32)) as f64;
                self.timeline.retry_s += self.cfg.launch_latency_s + backoff;
                self.timeline.retries += 1;
            }
            if let LaunchStatus::Fault(code) = backend.launch_status(None) {
                self.faults = Some((session, policy));
                return Err(Error::Fault(format!(
                    "launch reported status {code:#x} without an injected fault"
                )));
            }
            self.faults = Some((session, policy));
        }
        self.charge_kernel(max_dpu_s);
        Ok(())
    }

    /// Charge host-side merge work of `elems` accumulator elements
    /// (parallelized over `host_threads`, OpenMP-style).
    pub fn charge_host_merge(&mut self, elems: u64) {
        let threads = self.cfg.host_threads.max(1) as f64;
        let per_thread = elems as f64 / threads;
        self.timeline.host_merge_s += per_thread / self.cfg.host_merge_rate;
    }
}

impl std::fmt::Debug for PimMachine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // Compact: banks hold up to 64 MiB each; render shape, not bytes.
        f.debug_struct("PimMachine")
            .field("n_dpus", &self.banks.len())
            .field("mram_used", &self.allocator.used())
            .field("total_s", &self.timeline.total_s())
            .field("faults", &self.faults.is_some())
            .field("sanitize", &self.sanitize)
            .field("xfer_records", &self.xfer_log.len())
            .finish_non_exhaustive()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn machine() -> PimMachine {
        PimMachine::new(PimConfig::tiny(4))
    }

    #[test]
    fn push_pull_roundtrip() {
        let mut m = machine();
        let addr = m.alloc(16).unwrap();
        let bufs: Vec<Vec<u8>> = (0..4).map(|d| vec![d as u8; 16]).collect();
        m.push_parallel(addr, &bufs).unwrap();
        let back = m.pull_parallel(addr, 16, 4).unwrap();
        assert_eq!(back, bufs);
        assert!(m.timeline().host_to_pim_s > 0.0);
        assert!(m.timeline().pim_to_host_s > 0.0);
        assert_eq!(m.timeline().bytes_h2p, 64);
        assert_eq!(m.timeline().bytes_p2h, 64);
    }

    #[test]
    fn parallel_requires_equal_sizes() {
        let mut m = machine();
        let addr = m.alloc(16).unwrap();
        let bufs = vec![vec![0u8; 16], vec![0u8; 8], vec![0u8; 16], vec![0u8; 16]];
        assert!(m.push_parallel(addr, &bufs).is_err());
    }

    #[test]
    fn broadcast_reaches_every_dpu() {
        let mut m = machine();
        let addr = m.alloc(8).unwrap();
        m.push_broadcast(addr, &[7u8; 8]).unwrap();
        for d in 0..4 {
            assert_eq!(m.read_bytes(d, addr, 8).unwrap(), vec![7u8; 8]);
        }
        // Broadcast counts payload once, not per-DPU.
        assert_eq!(m.timeline().bytes_h2p, 8);
    }

    #[test]
    fn kernel_charging_accumulates() {
        let mut m = machine();
        m.charge_kernel(0.5);
        m.charge_kernel(0.25);
        let t = m.timeline();
        assert_eq!(t.kernel_s, 0.75);
        assert_eq!(t.launches, 2);
        assert!(t.launch_s > 0.0);
        assert!(t.total_s() > 0.75);
    }

    #[test]
    fn alloc_addresses_shared_across_banks() {
        let mut m = machine();
        let a = m.alloc(64).unwrap();
        let b = m.alloc(64).unwrap();
        assert_ne!(a, b);
        m.write_bytes(0, a, &[1; 64]).unwrap();
        m.write_bytes(1, a, &[2; 64]).unwrap();
        assert_eq!(m.read_bytes(0, a, 1).unwrap()[0], 1);
        assert_eq!(m.read_bytes(1, a, 1).unwrap()[0], 2);
        m.free(a).unwrap();
        assert_eq!(m.mram_used(), 64);
    }

    #[test]
    fn sharded_row_io_matches_loop_based_transfers() {
        use crate::backend::{make, BackendKind};
        let exec = make(BackendKind::Parallel, 3).unwrap();
        let mut a = machine();
        let mut b = machine();
        let addr_a = a.alloc(16).unwrap();
        let addr_b = b.alloc(16).unwrap();
        let bufs: Vec<Vec<u8>> = (0..4).map(|d| vec![d as u8 + 1; 16]).collect();
        a.push_parallel(addr_a, &bufs).unwrap();
        b.push_rows_with(addr_b, 16, exec.as_ref(), &|dpu, buf| {
            buf.copy_from_slice(&bufs[dpu]);
        })
        .unwrap();
        // Identical bytes on every bank, identical modeled time.
        assert_eq!(a.timeline(), b.timeline());
        for d in 0..4 {
            assert_eq!(
                a.read_bytes(d, addr_a, 16).unwrap(),
                b.read_bytes(d, addr_b, 16).unwrap()
            );
        }
        let pa = a.pull_parallel(addr_a, 16, 4).unwrap();
        let pb = b.pull_rows_with(addr_b, 16, exec.as_ref(), &|_| 16).unwrap();
        let words: Vec<Vec<i32>> =
            pa.iter().map(|x| crate::coordinator::comm::bytes_to_words(x)).collect();
        assert_eq!(words, pb);
        assert_eq!(a.timeline(), b.timeline());
    }

    #[test]
    fn overlap_lane_subtracts_from_total() {
        let mut m = machine();
        m.charge_h2p(0.4, 1024);
        m.charge_kernel(0.2);
        m.charge_p2h(0.3, 512);
        let before = m.timeline().total_s();
        m.charge_overlap(0.25, 4);
        let t = m.timeline();
        assert!((t.total_s() - (before - 0.25)).abs() < 1e-12);
        assert_eq!(t.pipelined_launches, 1);
        assert_eq!(t.pipeline_chunks, 4);
        assert_eq!(t.bytes_h2p, 1024);
        assert_eq!(t.bytes_p2h, 512);
    }

    #[test]
    fn chunked_row_io_matches_monolithic() {
        use crate::pim::pipeline::byte_spans;
        let mut a = machine();
        let mut b = machine();
        let addr_a = a.alloc(64).unwrap();
        let addr_b = b.alloc(64).unwrap();
        let exec = crate::backend::make(crate::backend::BackendKind::Seq, 1).unwrap();
        let fill = |dpu: usize, buf: &mut [u8]| {
            for (i, x) in buf.iter_mut().enumerate() {
                *x = (dpu * 31 + i) as u8;
            }
        };
        a.write_rows_with(addr_a, 64, exec.as_ref(), &fill).unwrap();
        b.write_rows_chunked(addr_b, 64, &byte_spans(64, 5, 8), &fill).unwrap();
        for d in 0..4 {
            assert_eq!(
                a.read_bytes(d, addr_a, 64).unwrap(),
                b.read_bytes(d, addr_b, 64).unwrap()
            );
        }
        let take = |dpu: usize| if dpu == 2 { 0 } else { 36 }; // ragged + empty
        let ra = a.read_rows_with(addr_a, exec.as_ref(), &take).unwrap();
        let rb = b.read_rows_chunked(addr_b, &byte_spans(64, 5, 8), &take).unwrap();
        assert_eq!(ra, rb);
        // Chunked I/O is functional only: no modeled time.
        assert_eq!(b.timeline(), Timeline::default());
    }

    #[test]
    fn with_row_words_views_live_bytes() {
        let mut m = machine();
        let addr = m.alloc(16).unwrap();
        for d in 0..4 {
            let words: Vec<i32> = (0..4).map(|j| (d * 100 + j) as i32).collect();
            m.write_bytes(d, addr, &crate::coordinator::comm::words_to_bytes(&words)).unwrap();
        }
        // Ragged takes: DPU 2 contributes nothing, DPU 3 one word.
        let take = |dpu: usize| match dpu {
            2 => 0,
            3 => 4,
            _ => 16,
        };
        let sums = m
            .with_row_words(addr, &take, |views| {
                assert_eq!(views.len(), 4);
                views.iter().map(|v| v.iter().sum::<i32>()).collect::<Vec<i32>>()
            })
            .unwrap();
        assert_eq!(sums, vec![6, 100 + 101 + 102 + 103, 0, 300]);
        // Functional only: nothing charged.
        assert_eq!(m.timeline(), Timeline::default());
    }

    #[test]
    fn merge_lane_charges_accumulate_and_subtract_overlap() {
        let mut m = machine();
        m.charge_merge(0.2, 0.5, 31, 5);
        m.charge_merge(0.1, 0.2, 7, 0);
        let t = m.timeline();
        assert_eq!(t.merges, 2);
        assert_eq!(t.merge_elems, 38);
        assert_eq!(t.merge_levels, 5);
        assert!((t.merge_s - 0.3).abs() < 1e-12);
        assert!((t.merge_serial_s - 0.7).abs() < 1e-12);
        assert!((t.total_s() - 0.3).abs() < 1e-12, "merge lane counts in total");
        assert!((t.comm_s() - 0.3).abs() < 1e-12);
        m.charge_merge_overlap(0.05, 8);
        let t = m.timeline();
        assert_eq!(t.pipelined_merges, 1);
        assert_eq!(t.merge_chunks, 8);
        assert_eq!(t.pipeline_chunks, 0, "kernel-pipeline counters untouched");
        assert_eq!(t.pipelined_launches, 0, "a merge is not a kernel launch");
        assert_eq!(t.overlap_saved_s, 0.0, "kernel overlap lane stays merge-free");
        assert!((t.total_s() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn dpu_set_split_covers_machine_with_proportional_shares() {
        let parent = PimConfig::upmem(32);
        let sets = DpuSet::split(&parent, 4).unwrap();
        assert_eq!(sets.len(), 4);
        let mut next = 0;
        for s in &sets {
            assert_eq!(s.first_dpu, next, "contiguous partitions");
            assert_eq!(s.n_dpus, 8);
            assert_eq!(s.cfg().n_dpus, 8);
            next += s.n_dpus;
        }
        assert_eq!(next, 32, "full coverage");
        // Bus shares sum to the parent's aggregate bandwidth: P tenants
        // transferring at once never model more than the machine had.
        let share_sum: f64 = sets.iter().map(|s| s.cfg().parallel_bw()).sum();
        assert!((share_sum - parent.parallel_bw()).abs() < 1.0, "{share_sum}");
        // Host threads split proportionally too.
        assert_eq!(sets[0].cfg().host_threads, parent.host_threads / 4);
        // Per-DPU constants are untouched.
        assert_eq!(sets[0].cfg().mram_bytes, parent.mram_bytes);
        assert_eq!(sets[0].cfg().freq_hz, parent.freq_hz);
    }

    #[test]
    fn dpu_set_split_rejects_bad_counts_with_diagnostics() {
        let parent = PimConfig::upmem(32);
        for (parts, needle) in [(0usize, "0"), (5, "5"), (33, "33")] {
            let err = DpuSet::split(&parent, parts).err().expect("must fail");
            assert!(matches!(err, Error::Config(_)), "{err}");
            assert!(err.to_string().contains(needle), "offending value in message: {err}");
        }
        // A whole-machine "partitioning" is the degenerate identity.
        let whole = DpuSet::split(&parent, 1).unwrap();
        assert_eq!(whole.len(), 1);
        assert_eq!(whole[0].n_dpus, 32);
        assert!((whole[0].cfg().parallel_bw() - parent.parallel_bw()).abs() < 1.0);
        assert_eq!(whole[0].cfg().host_threads, parent.host_threads);

        // ...including when the parent's bandwidth ceiling binds (many
        // ranks): per-rank bandwidth is preserved, so a partial-rank
        // transfer models identically on the split(1) view.
        let big = PimConfig::upmem(4096); // 64 ranks, raw bw > ceiling
        let whole = DpuSet::split(&big, 1).unwrap();
        assert_eq!(whole[0].cfg().xfer_rank_bw, big.xfer_rank_bw);
        assert!((whole[0].cfg().parallel_bw() - big.parallel_bw()).abs() < 1.0);
        let one_rank_before =
            crate::pim::xfer::transfer_seconds(&big, crate::pim::XferKind::Parallel, 64, 1024);
        let one_rank_after = crate::pim::xfer::transfer_seconds(
            whole[0].cfg(),
            crate::pim::XferKind::Parallel,
            64,
            1024,
        );
        assert!((one_rank_before - one_rank_after).abs() < 1e-15, "partial-rank identity");
    }

    #[test]
    fn dpu_set_split_cuts_along_rank_boundaries() {
        // 2 channels x 4 ranks x 4 DPUs/rank: 8-rank tree over 32 DPUs.
        let parent = PimConfig::upmem(32).with_topology(2, 4).unwrap();

        // 2 parts of 16 DPUs = one whole channel each.
        let halves = DpuSet::split(&parent, 2).unwrap();
        assert_eq!(halves[0].cfg().n_channels, 1);
        assert_eq!(halves[0].cfg().ranks_per_channel, 4);
        assert_eq!(halves[0].cfg().n_ranks(), 4);
        // Each half owns 4 real rank engines — and its bus share says
        // exactly that (4 x 350 MB/s), not a fraction of one flat bus.
        assert!((halves[0].cfg().parallel_bw() - 4.0 * 350e6).abs() < 1.0);

        // 8 parts of 4 DPUs = one rank each.
        let ranks = DpuSet::split(&parent, 8).unwrap();
        assert!((ranks[0].cfg().parallel_bw() - 350e6).abs() < 1.0);

        // 16 parts of 2 DPUs would straddle ranks: hard error.
        let err = DpuSet::split(&parent, 16).err().expect("straddling split must fail");
        assert!(matches!(err, Error::Config(_)));
        assert!(err.to_string().contains("rank boundary"), "{err}");

        // split(cfg, 1) stays the identity, topology included.
        let whole = DpuSet::split(&parent, 1).unwrap();
        assert_eq!(whole[0].cfg().n_channels, 2);
        assert_eq!(whole[0].cfg().ranks_per_channel, 4);
        assert!((whole[0].cfg().parallel_bw() - parent.parallel_bw()).abs() < 1.0);
    }

    #[test]
    fn partition_machines_account_independent_timelines() {
        let parent = PimMachine::new(PimConfig::tiny(8));
        let sets = parent.partition(2).unwrap();
        let mut a = sets[0].machine();
        let mut b = sets[1].machine();
        assert_eq!(a.n_dpus(), 4);
        assert_eq!(b.n_dpus(), 4);
        a.charge_kernel(0.5);
        assert_eq!(b.timeline(), Timeline::default(), "per-partition lanes are disjoint");
        assert!(a.timeline().kernel_s > 0.0);
        // A partition's parallel transfer runs at its bus share, so it
        // models slower than the whole machine moving the same row.
        let addr_a = a.alloc(1024).unwrap();
        let bufs: Vec<Vec<u8>> = (0..4).map(|_| vec![1u8; 1024]).collect();
        a.push_parallel(addr_a, &bufs).unwrap();
        let mut whole = PimMachine::new(PimConfig::tiny(8));
        let addr_w = whole.alloc(1024).unwrap();
        let bufs8: Vec<Vec<u8>> = (0..8).map(|_| vec![1u8; 1024]).collect();
        whole.push_parallel(addr_w, &bufs8).unwrap();
        // Half the DPUs at half the bandwidth: same modeled seconds for
        // half the bytes is the break-even the share rule enforces.
        assert!(a.timeline().host_to_pim_s >= whole.timeline().host_to_pim_s * 0.99);
    }

    #[test]
    fn reset_timeline_keeps_state() {
        let mut m = machine();
        let addr = m.alloc(8).unwrap();
        m.push_broadcast(addr, &[9u8; 8]).unwrap();
        m.reset_timeline();
        assert_eq!(m.timeline(), Timeline::default());
        assert_eq!(m.read_bytes(2, addr, 8).unwrap(), vec![9u8; 8]);
    }

    #[test]
    fn merge_of_a_full_split_is_the_identity() {
        let cfg = PimConfig::tiny(8);
        let sets = DpuSet::split(&cfg, 4).unwrap();
        let merged = DpuSet::merge(&cfg, &sets).unwrap();
        let whole = &DpuSet::split(&cfg, 1).unwrap()[0];
        assert_eq!(merged.first_dpu, 0);
        assert_eq!(merged.n_dpus, 8);
        assert_eq!(merged.cfg().n_dpus, whole.cfg().n_dpus);
        assert_eq!(merged.cfg().xfer_bw_ceiling, whole.cfg().xfer_bw_ceiling);
        assert_eq!(merged.cfg().host_threads, whole.cfg().host_threads);
    }

    #[test]
    fn partial_merge_gets_the_proportional_share() {
        let cfg = PimConfig::tiny(8);
        let sets = DpuSet::split(&cfg, 4).unwrap();
        let merged = DpuSet::merge(&cfg, &sets[1..3]).unwrap();
        assert_eq!(merged.first_dpu, 2);
        assert_eq!(merged.n_dpus, 4);
        // Same share as any 4-DPU partition produced by split directly.
        let half = &DpuSet::split(&cfg, 2).unwrap()[0];
        assert_eq!(merged.cfg().xfer_bw_ceiling, half.cfg().xfer_bw_ceiling);
        assert_eq!(merged.cfg().host_threads, half.cfg().host_threads);
    }

    #[test]
    fn merge_rejects_gaps_and_empty_input() {
        let cfg = PimConfig::tiny(8);
        let sets = DpuSet::split(&cfg, 4).unwrap();
        let gapped = [sets[0].clone(), sets[2].clone()];
        let err = DpuSet::merge(&cfg, &gapped).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        assert!(err.to_string().contains("non-adjacent"), "{err}");
        assert!(DpuSet::merge(&cfg, &[]).is_err());
    }

    #[test]
    fn fault_guard_charges_only_the_retry_lane_and_never_the_bits() {
        let spec = FaultSpec { seed: 11, rate: 0.6, dead_rank: None, dead_at_s: 0.0 };
        let mut clean = machine();
        let mut faulty = machine();
        faulty.install_faults(
            &spec,
            0,
            RecoveryPolicy { retry_budget: 64, ..RecoveryPolicy::default() },
        );
        let addr_c = clean.alloc(32).unwrap();
        let addr_f = faulty.alloc(32).unwrap();
        let bufs: Vec<Vec<u8>> = (0..4).map(|d| vec![d as u8 + 1; 32]).collect();
        for _ in 0..8 {
            clean.push_parallel(addr_c, &bufs).unwrap();
            faulty.push_parallel(addr_f, &bufs).unwrap();
        }
        let (tc, tf) = (clean.timeline(), faulty.timeline());
        // Phase lanes carry only the successful attempts — identical to
        // the fault-free run; recovery cost lives on the retry lane.
        assert_eq!(tc.host_to_pim_s, tf.host_to_pim_s);
        assert_eq!(tc.bytes_h2p, tf.bytes_h2p);
        assert!(tf.faults_injected > 0, "rate 0.6 over 8 pushes must fire");
        assert_eq!(tf.retries, tf.faults_injected, "every fault was absorbed");
        assert!(tf.retry_s > 0.0);
        assert!((tf.total_s() - (tc.total_s() + tf.retry_s)).abs() < 1e-12);
        assert_eq!(faulty.fault_events().len(), tf.faults_injected as usize);
        for d in 0..4 {
            assert_eq!(
                clean.read_bytes(d, addr_c, 32).unwrap(),
                faulty.read_bytes(d, addr_f, 32).unwrap(),
                "recovered bits identical to fault-free bits"
            );
        }
    }

    #[test]
    fn guarded_launch_dead_letters_when_the_budget_is_exhausted() {
        let exec = crate::backend::make(crate::backend::BackendKind::Seq, 1).unwrap();
        let mut m = machine();
        let hot = FaultSpec { seed: 5, rate: 1.0, dead_rank: None, dead_at_s: 0.0 };
        m.install_faults(&hot, 0, RecoveryPolicy { retry_budget: 3, ..RecoveryPolicy::default() });
        let err = m.guarded_launch(0.5, exec.as_ref()).unwrap_err();
        assert!(matches!(err, Error::Fault(_)), "{err}");
        assert!(err.to_string().contains("dead-letter"), "{err}");
        assert!(err.to_string().contains("rank"), "attribution in the message: {err}");
        let t = m.timeline();
        assert_eq!(t.faults_injected, 4, "3 absorbed + the killing fault");
        assert_eq!(t.retries, 3);
        assert_eq!(t.launches, 0, "the launch never succeeded");
        assert_eq!(t.kernel_s, 0.0);
        // With a calm plan the guard passes through to a normal charge.
        let calm = FaultSpec { seed: 5, rate: 0.0, dead_rank: None, dead_at_s: 0.0 };
        let mut m = machine();
        m.install_faults(&calm, 0, RecoveryPolicy::default());
        m.guarded_launch(0.5, exec.as_ref()).unwrap();
        let t = m.timeline();
        assert_eq!((t.launches, t.kernel_s, t.retry_s), (1, 0.5, 0.0));
    }

    #[test]
    fn sanitizer_records_transfers_without_perturbing_time_or_bytes() {
        let mut plain = machine();
        let mut armed = machine();
        armed.set_sanitizer(true);
        assert!(armed.sanitizer_enabled());
        let addr_p = plain.alloc(16).unwrap();
        let addr_a = armed.alloc(16).unwrap();
        let bufs: Vec<Vec<u8>> = (0..4).map(|d| vec![d as u8 + 1; 16]).collect();
        plain.push_parallel(addr_p, &bufs).unwrap();
        armed.push_parallel(addr_a, &bufs).unwrap();
        let rp = plain.pull_parallel(addr_p, 16, 4).unwrap();
        let ra = armed.pull_parallel(addr_a, 16, 4).unwrap();
        assert_eq!(rp, ra, "sanitizer never touches functional bytes");
        assert_eq!(plain.timeline(), armed.timeline(), "...or modeled time");
        assert!(plain.xfer_log().is_empty(), "disarmed machines record nothing");
        let log = armed.xfer_log();
        assert_eq!(log.len(), 2);
        assert!(log[0].write && !log[1].write);
        assert_eq!((log[0].addr, log[0].row_len), (addr_a, 16));
        assert_eq!(log[0].digest, log[1].digest, "untouched region digests agree");
        // Re-arming opens a fresh window.
        armed.set_sanitizer(true);
        assert_eq!(armed.xfer_log().len(), 2, "arming while armed keeps the log");
        armed.set_sanitizer(false);
        armed.set_sanitizer(true);
        assert!(armed.xfer_log().is_empty());
    }

    #[test]
    fn sanitizer_sees_out_of_band_corruption() {
        let mut m = machine();
        m.set_sanitizer(true);
        let addr = m.alloc(16).unwrap();
        let bufs: Vec<Vec<u8>> = (0..4).map(|_| vec![3u8; 16]).collect();
        m.push_parallel(addr, &bufs).unwrap();
        // write_bytes is deliberately unrecorded: it is the raw debug
        // backdoor, so a byte smashed through it shows up as a digest
        // mismatch on the next recorded read.
        m.write_bytes(2, addr, &[0xFF]).unwrap();
        m.pull_parallel(addr, 16, 4).unwrap();
        let log = m.xfer_log();
        assert_eq!(log.len(), 2);
        assert_ne!(log[0].digest, log[1].digest, "corruption must change the digest");
    }

    #[test]
    fn merge_respects_rank_boundaries() {
        // 2 channels x 2 ranks/channel x 4 DPUs/rank = 16 DPUs.
        let cfg = PimConfig::tiny(16).with_topology(2, 2).unwrap();
        let sets = DpuSet::split(&cfg, 4).unwrap();
        // Whole-rank merges are fine and re-group into channels.
        let merged = DpuSet::merge(&cfg, &sets[0..2]).unwrap();
        assert_eq!(merged.cfg().n_channels * merged.cfg().ranks_per_channel, 2);
        // A hand-built sub-rank set must be refused.
        let mut sub = sets[0].clone();
        sub.n_dpus = 2;
        sub.cfg.n_dpus = 2;
        let err = DpuSet::merge(&cfg, &[sub]).unwrap_err();
        assert!(err.to_string().contains("rank boundary"), "{err}");
    }
}
