//! The PIM substrate: a performance-model simulator of the UPMEM-like
//! machine the paper targets.
//!
//! The paper evaluates on real hardware we do not have; DESIGN.md §2
//! explains why this simulator preserves the paper's performance
//! *mechanisms*: instruction-mix costs ([`isa`]), fine-grained
//! multithreaded pipeline occupancy ([`pipeline`]), WRAM<->MRAM DMA batch
//! amortization ([`dma`]), and rank-parallel host<->PIM transfers
//! ([`xfer`]).  [`device::PimMachine`] assembles them plus functional
//! per-bank byte storage ([`memory`]); [`sdk`] exposes the raw
//! UPMEM-SDK-style API the hand-optimized baselines are written against.

pub mod config;
pub mod device;
pub mod dma;
pub mod faults;
pub mod isa;
pub mod memory;
pub mod pipeline;
pub mod sdk;
pub mod xfer;

pub use config::PimConfig;
pub use device::{DpuSet, PimMachine, Timeline};
pub use faults::{FaultEvent, FaultKind, FaultSession, FaultSpec, RecoveryPolicy};
pub use isa::{slots, InstrMix, Op};
pub use pipeline::{ChunkPlan, PipeSchedule, PipelineMode};
pub use xfer::{transfer_seconds, XferKind};
