//! Machine description of the simulated UPMEM-like PIM system.
//!
//! Constants follow the first-generation UPMEM architecture as described
//! in the paper (§2) and the PrIM characterization papers [26, 53]:
//! 450 MHz DPUs with an 11-stage fine-grained multithreaded pipeline, a
//! 64 KB WRAM scratchpad, a 24 KB IRAM, one 64 MB MRAM bank per DPU,
//! 8-byte-aligned WRAM<->MRAM DMA capped at 2,048 bytes per transfer, and
//! host<->PIM parallel transfer commands whose bandwidth scales with the
//! number of ranks.

use crate::error::{Error, Result};

/// Full machine description (PIM side + host side).
#[derive(Debug, Clone)]
pub struct PimConfig {
    /// Number of DPUs (PIM cores) in the system.
    pub n_dpus: usize,
    /// DPUs per rank (UPMEM: 64 = 8 chips x 8 banks).
    pub dpus_per_rank: usize,
    /// DPU clock frequency in Hz (UPMEM: 450 MHz).
    pub freq_hz: f64,
    /// Pipeline depth; >= this many tasklets fully utilize the core.
    pub pipeline_depth: u32,
    /// Maximum hardware tasklets per DPU (UPMEM: 24).
    pub max_tasklets: u32,
    /// Default tasklets launched by SimplePIM iterators (paper: 12).
    pub default_tasklets: u32,
    /// WRAM scratchpad bytes per DPU (UPMEM: 64 KB).
    pub wram_bytes: u64,
    /// WRAM bytes reserved for stack/runtime, unavailable to accumulators
    /// and streaming buffers.
    pub wram_reserved_bytes: u64,
    /// IRAM bytes per DPU (UPMEM: 24 KB) — bounds unrolling depth.
    pub iram_bytes: u64,
    /// MRAM bank bytes per DPU (UPMEM: 64 MB).
    pub mram_bytes: u64,
    /// Required alignment for WRAM<->MRAM DMA (UPMEM: 8 bytes).
    pub dma_align: u64,
    /// Maximum bytes per single WRAM<->MRAM DMA (UPMEM: 2,048).
    pub dma_max_bytes: u64,
    /// Fixed DMA issue cost in DPU cycles (per `mram_read`/`mram_write`).
    pub dma_setup_cycles: u64,
    /// DMA streaming throughput in bytes per DPU cycle once started.
    /// ~800 MB/s per bank at 450 MHz ~= 1.78 B/cycle.
    pub dma_bytes_per_cycle: f64,
    /// Host->PIM / PIM->host parallel-transfer bandwidth per rank (B/s).
    pub xfer_rank_bw: f64,
    /// Ceiling on aggregate host<->PIM bandwidth across ranks (B/s).
    pub xfer_bw_ceiling: f64,
    /// Memory channels the ranks are spread across (DESIGN.md §15).
    /// `1` together with `ranks_per_channel == 1` is the flat sentinel:
    /// ranks derive from `dpus_per_rank` and all bandwidth flows
    /// through the single aggregate bus, exactly the pre-topology
    /// model.  Set both via [`Self::with_topology`].
    pub n_channels: usize,
    /// Ranks behind each memory channel (flat sentinel: 1, see
    /// [`Self::n_channels`]).
    pub ranks_per_channel: usize,
    /// Per-channel bus bandwidth cap (B/s).  At the default it equals
    /// the aggregate ceiling, so a single channel never binds below
    /// `xfer_bw_ceiling`; lower it to model channel-starved parts.
    pub xfer_channel_bw: f64,
    /// Serial (single-DPU) transfer bandwidth (B/s).
    pub xfer_serial_bw: f64,
    /// Fixed software latency per host<->PIM transfer command (s).
    pub xfer_latency_s: f64,
    /// Fixed cost of launching a PIM kernel on all DPUs (s).
    pub launch_latency_s: f64,
    /// Host CPU: threads used for merging partials (OpenMP analog).
    pub host_threads: usize,
    /// Host CPU: sustained merge throughput per thread (elements/s).
    pub host_merge_rate: f64,
    /// Pipelined transfer engine (DESIGN.md §12): nominal per-DPU chunk
    /// size for double-buffered chunked scatter/gather.
    pub pipeline_chunk_bytes: u64,
    /// Upper bound on chunks per pipelined launch.
    pub pipeline_max_chunks: usize,
    /// Staging buffers per transfer direction (2 = double buffering).
    pub pipeline_in_flight: usize,
}

impl PimConfig {
    /// UPMEM-like machine with `n_dpus` DPUs and paper-calibrated
    /// constants.
    pub fn upmem(n_dpus: usize) -> Self {
        PimConfig {
            n_dpus,
            dpus_per_rank: 64,
            freq_hz: 450e6,
            pipeline_depth: 11,
            max_tasklets: 24,
            default_tasklets: 12,
            wram_bytes: 64 * 1024,
            wram_reserved_bytes: 4 * 1024,
            iram_bytes: 24 * 1024,
            mram_bytes: 64 * 1024 * 1024,
            dma_align: 8,
            dma_max_bytes: 2048,
            // PrIM [26]: MRAM latency is ~ linear in size with a fixed
            // setup; 2,048 B transfers reach ~2 B/cycle peak.
            dma_setup_cycles: 64,
            dma_bytes_per_cycle: 2.0,
            // PrIM [26]: parallel transfers scale with ranks;
            // ~350 MB/s/rank effective, saturating around 16 GB/s.
            xfer_rank_bw: 350e6,
            xfer_bw_ceiling: 16e9,
            n_channels: 1,
            ranks_per_channel: 1,
            xfer_channel_bw: 16e9,
            xfer_serial_bw: 600e6,
            xfer_latency_s: 20e-6,
            launch_latency_s: 0.25e-3,
            host_threads: 32,
            host_merge_rate: 400e6,
            // Pipelined transfers: 64 KB chunks amortize the per-command
            // latency (20 µs ≈ 0.3% of a 64 KB rank push) while keeping
            // the double-buffered MRAM staging region small.
            pipeline_chunk_bytes: 64 * 1024,
            pipeline_max_chunks: 64,
            pipeline_in_flight: 2,
        }
    }

    /// The 608-DPU configuration the paper's scaling study starts from.
    pub fn upmem_608() -> Self {
        Self::upmem(608)
    }

    /// The full evaluated system (paper: 2,432 DPUs).
    pub fn upmem_2432() -> Self {
        Self::upmem(2432)
    }

    /// A tiny machine for functional tests: few DPUs, small MRAM so
    /// capacity errors are reachable, same alignment rules.
    pub fn tiny(n_dpus: usize) -> Self {
        let mut cfg = Self::upmem(n_dpus);
        cfg.mram_bytes = 8 * 1024 * 1024;
        cfg
    }

    /// Declare an explicit `channel -> rank -> DPU` topology
    /// (DESIGN.md §15).  The flat machine stays expressible as 1x1, so
    /// `with_topology(1, 1)` is the identity.  Degenerate shapes are
    /// hard config errors, never silently clamped: zero channels or
    /// ranks, more ranks than DPUs, and DPU counts the rank grid does
    /// not divide are all rejected.
    pub fn with_topology(mut self, channels: usize, ranks_per_channel: usize) -> Result<Self> {
        if channels == 0 || ranks_per_channel == 0 {
            return Err(Error::Config(format!(
                "topology {channels}x{ranks_per_channel}: channels and ranks must be >= 1"
            )));
        }
        let ranks = channels * ranks_per_channel;
        if ranks > self.n_dpus {
            return Err(Error::Config(format!(
                "topology {channels}x{ranks_per_channel}: {ranks} ranks exceed {} DPUs",
                self.n_dpus
            )));
        }
        if self.n_dpus % ranks != 0 {
            return Err(Error::Config(format!(
                "topology {channels}x{ranks_per_channel}: {} DPUs not divisible into {ranks} equal ranks",
                self.n_dpus
            )));
        }
        self.n_channels = channels;
        self.ranks_per_channel = ranks_per_channel;
        Ok(self)
    }

    /// Whether a `channel -> rank -> DPU` tree was declared (vs the
    /// flat 1x1 sentinel where ranks derive from `dpus_per_rank`).
    pub fn explicit_topology(&self) -> bool {
        self.n_channels > 1 || self.ranks_per_channel > 1
    }

    /// Number of ranks (ceil division: a partial rank still burns a rank
    /// slot on the bus).  With an explicit topology the declared grid
    /// is authoritative.
    pub fn n_ranks(&self) -> usize {
        if self.explicit_topology() {
            self.n_channels * self.ranks_per_channel
        } else {
            self.n_dpus.div_ceil(self.dpus_per_rank)
        }
    }

    /// DPUs behind one rank's transfer engine.
    pub fn rank_dpus(&self) -> usize {
        if self.explicit_topology() {
            // `with_topology` validated divisibility; div_ceil keeps
            // hand-built configs from rounding a partial rank to zero.
            self.n_dpus.div_ceil(self.n_ranks())
        } else {
            self.dpus_per_rank
        }
    }

    /// `(rank_dpus, ranks_per_channel)` for the hierarchical merge
    /// (`ExecBackend::combine_rows_topo`): on a flat machine the
    /// grouping is disabled (`rank_dpus = n_dpus` makes every grouped
    /// combine fall back to the flat tree), so the PR 4 merge order —
    /// and the gang backend's per-level batch counters — are untouched
    /// unless a topology was declared.
    pub fn merge_grouping(&self) -> (usize, usize) {
        if self.explicit_topology() {
            (self.rank_dpus(), self.ranks_per_channel)
        } else {
            (self.n_dpus.max(1), 1)
        }
    }

    /// Channels a transfer touching `ranks_used` ranks spreads across.
    /// The flat machine is a single bus: everything shares one channel.
    pub fn channels_used(&self, ranks_used: usize) -> usize {
        if self.explicit_topology() {
            ranks_used.div_ceil(self.ranks_per_channel).min(self.n_channels).max(1)
        } else {
            1
        }
    }

    /// Effective aggregate parallel-transfer bandwidth in B/s: rank
    /// engines in parallel, capped per channel bus and by the global
    /// ceiling.  Flat configs see `channels_used = 1` with the channel
    /// cap at the ceiling, reproducing the pre-topology number exactly.
    pub fn parallel_bw(&self) -> f64 {
        let ranks = self.n_ranks();
        (ranks as f64 * self.xfer_rank_bw)
            .min(self.channels_used(ranks) as f64 * self.xfer_channel_bw)
            .min(self.xfer_bw_ceiling)
    }

    /// WRAM bytes usable by iterator buffers/accumulators.
    pub fn wram_available(&self) -> u64 {
        self.wram_bytes - self.wram_reserved_bytes
    }

    /// Human-readable machine shape, shared by `--explain` reports and
    /// job-error attribution so every surface prints the same string.
    pub fn topology_desc(&self) -> String {
        if self.explicit_topology() {
            format!(
                "{} channel(s) x {} rank(s)/channel x {} DPU(s)/rank",
                self.n_channels,
                self.ranks_per_channel,
                self.rank_dpus()
            )
        } else {
            format!(
                "flat bus, {} rank(s) x <= {} DPU(s)/rank",
                self.n_ranks(),
                self.dpus_per_rank.min(self.n_dpus)
            )
        }
    }
}

impl Default for PimConfig {
    fn default() -> Self {
        Self::upmem_2432()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_count() {
        assert_eq!(PimConfig::upmem(608).n_ranks(), 10);
        assert_eq!(PimConfig::upmem(2432).n_ranks(), 38);
        assert_eq!(PimConfig::upmem(64).n_ranks(), 1);
        assert_eq!(PimConfig::upmem(65).n_ranks(), 2);
    }

    #[test]
    fn parallel_bw_scales_then_saturates() {
        let small = PimConfig::upmem(64);
        let mid = PimConfig::upmem(608);
        let big = PimConfig::upmem(4096);
        assert!(small.parallel_bw() < mid.parallel_bw());
        assert_eq!(big.parallel_bw(), big.xfer_bw_ceiling);
    }

    #[test]
    fn explicit_topology_overrides_rank_derivation() {
        let cfg = PimConfig::upmem(32).with_topology(2, 4).unwrap();
        assert!(cfg.explicit_topology());
        assert_eq!(cfg.n_ranks(), 8);
        assert_eq!(cfg.rank_dpus(), 4);
        assert_eq!(cfg.channels_used(8), 2);
        assert_eq!(cfg.channels_used(3), 1);
        assert_eq!(cfg.channels_used(5), 2);
        // 8 rank engines beat the flat single partial rank 8x.
        let flat = PimConfig::upmem(32);
        assert_eq!(flat.parallel_bw(), 350e6);
        assert_eq!(cfg.parallel_bw(), 8.0 * 350e6);
    }

    #[test]
    fn flat_sentinel_is_the_identity() {
        let base = PimConfig::upmem(608);
        let one = base.clone().with_topology(1, 1).unwrap();
        assert!(!one.explicit_topology());
        assert_eq!(one.n_ranks(), base.n_ranks());
        assert_eq!(one.rank_dpus(), base.dpus_per_rank);
        assert_eq!(one.channels_used(10), 1);
        assert_eq!(one.parallel_bw(), base.parallel_bw());
    }

    #[test]
    fn topology_degenerates_are_config_errors() {
        assert!(PimConfig::upmem(32).with_topology(0, 4).is_err());
        assert!(PimConfig::upmem(32).with_topology(2, 0).is_err());
        // More ranks than DPUs.
        assert!(PimConfig::upmem(6).with_topology(2, 4).is_err());
        // 32 DPUs do not divide into 3 equal ranks.
        assert!(PimConfig::upmem(32).with_topology(1, 3).is_err());
        // Exactly one DPU per rank is legal.
        let cfg = PimConfig::upmem(8).with_topology(2, 4).unwrap();
        assert_eq!(cfg.rank_dpus(), 1);
    }

    #[test]
    fn channel_cap_binds_when_lowered() {
        let mut cfg = PimConfig::upmem(2048).with_topology(2, 16).unwrap();
        // 32 ranks x 350 MB/s = 11.2 GB/s, under the 16 GB/s ceiling.
        assert_eq!(cfg.parallel_bw(), 32.0 * 350e6);
        cfg.xfer_channel_bw = 2e9;
        assert_eq!(cfg.parallel_bw(), 4e9, "2 channels x 2 GB/s bind first");
    }

    #[test]
    fn wram_budget_positive() {
        let cfg = PimConfig::default();
        assert!(cfg.wram_available() > 0);
        assert!(cfg.wram_available() < cfg.wram_bytes);
    }
}
