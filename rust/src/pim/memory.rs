//! Simulated MRAM bank: byte-addressed storage + a first-fit allocator.
//!
//! Every DPU owns one bank.  SimplePIM allocates the *same address range
//! on every bank* for a distributed array (the UPMEM SDK symbol/offset
//! model), so the allocator lives logically at the machine level and the
//! banks just hold bytes; see [`super::device::PimMachine`].

use crate::error::{Error, Result};

/// One DPU's MRAM bank.
///
/// Banks are plain byte arrays with no interior mutability, so they
/// are `Send + Sync` by construction: the execution-backend layer
/// ([`crate::backend`]) relies on this to hand disjoint
/// `&mut [MramBank]` *rank shards* to `std::thread::scope` workers for
/// parallel row marshalling (asserted below so a future field can't
/// silently break the contract).
#[derive(Debug, Clone)]
pub struct MramBank {
    data: Vec<u8>,
}

const _: () = {
    const fn assert_rank_shardable<T: Send + Sync>() {}
    assert_rank_shardable::<MramBank>()
};

impl MramBank {
    pub fn new(bytes: u64) -> Self {
        MramBank { data: vec![0u8; bytes as usize] }
    }

    pub fn capacity(&self) -> u64 {
        self.data.len() as u64
    }

    /// Read `len` bytes at `addr`.
    pub fn read(&self, addr: u64, len: u64) -> Result<&[u8]> {
        let end = addr
            .checked_add(len)
            .filter(|&e| e <= self.capacity())
            .ok_or_else(|| Error::Capacity(format!("MRAM read {addr:#x}+{len} out of range")))?;
        Ok(&self.data[addr as usize..end as usize])
    }

    /// Write `bytes` at `addr`.
    pub fn write(&mut self, addr: u64, bytes: &[u8]) -> Result<()> {
        let end = addr
            .checked_add(bytes.len() as u64)
            .filter(|&e| e <= self.capacity())
            .ok_or_else(|| {
                Error::Capacity(format!("MRAM write {addr:#x}+{} out of range", bytes.len()))
            })?;
        self.data[addr as usize..end as usize].copy_from_slice(bytes);
        Ok(())
    }
}

/// First-fit allocator handing out address ranges valid on *all* banks.
#[derive(Debug, Clone, Default)]
pub struct MramAllocator {
    /// (addr, size) of live allocations, sorted by addr.
    live: Vec<(u64, u64)>,
    capacity: u64,
    align: u64,
}

impl MramAllocator {
    pub fn new(capacity: u64, align: u64) -> Self {
        MramAllocator { live: Vec::new(), capacity, align }
    }

    /// Allocate `size` bytes (rounded up to alignment); first-fit.
    pub fn alloc(&mut self, size: u64) -> Result<u64> {
        let size = crate::util::round_up(size.max(1), self.align);
        let mut addr = 0u64;
        for (i, &(a, s)) in self.live.iter().enumerate() {
            if addr + size <= a {
                self.live.insert(i, (addr, size));
                return Ok(addr);
            }
            addr = a + s;
        }
        if addr + size <= self.capacity {
            self.live.push((addr, size));
            Ok(addr)
        } else {
            Err(Error::Capacity(format!(
                "MRAM exhausted: need {size} B at {addr:#x}, capacity {}",
                self.capacity
            )))
        }
    }

    /// Free the allocation starting at `addr`.
    pub fn free(&mut self, addr: u64) -> Result<()> {
        match self.live.iter().position(|&(a, _)| a == addr) {
            Some(i) => {
                self.live.remove(i);
                Ok(())
            }
            None => Err(Error::Capacity(format!("free of unallocated address {addr:#x}"))),
        }
    }

    /// Bytes currently allocated.
    pub fn used(&self) -> u64 {
        self.live.iter().map(|&(_, s)| s).sum()
    }

    /// Number of live allocations.
    pub fn live_count(&self) -> usize {
        self.live.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bank_roundtrip() {
        let mut b = MramBank::new(1024);
        b.write(8, &[1, 2, 3, 4]).unwrap();
        assert_eq!(b.read(8, 4).unwrap(), &[1, 2, 3, 4]);
        assert_eq!(b.read(12, 2).unwrap(), &[0, 0]);
    }

    #[test]
    fn bank_bounds_checked() {
        let mut b = MramBank::new(16);
        assert!(b.write(12, &[0; 8]).is_err());
        assert!(b.read(u64::MAX, 2).is_err());
    }

    #[test]
    fn alloc_is_aligned_and_first_fit() {
        let mut a = MramAllocator::new(1024, 8);
        let p0 = a.alloc(10).unwrap(); // rounds to 16
        let p1 = a.alloc(32).unwrap();
        assert_eq!(p0, 0);
        assert_eq!(p1, 16);
        a.free(p0).unwrap();
        let p2 = a.alloc(8).unwrap(); // fits in the hole
        assert_eq!(p2, 0);
        let p3 = a.alloc(16).unwrap(); // hole too small now? 8..16 free
        assert_eq!(p3, 48.min(p3)); // appended after p1 or in hole if fits
        assert_eq!(a.live_count(), 3);
    }

    #[test]
    fn alloc_exhausts() {
        let mut a = MramAllocator::new(64, 8);
        a.alloc(32).unwrap();
        a.alloc(32).unwrap();
        assert!(a.alloc(8).is_err());
    }

    #[test]
    fn free_unknown_errors() {
        let mut a = MramAllocator::new(64, 8);
        assert!(a.free(0).is_err());
        let p = a.alloc(8).unwrap();
        a.free(p).unwrap();
        assert!(a.free(p).is_err());
    }

    #[test]
    fn used_tracks_live_bytes() {
        let mut a = MramAllocator::new(1 << 20, 8);
        assert_eq!(a.used(), 0);
        let p = a.alloc(100).unwrap();
        assert_eq!(a.used(), 104); // rounded up
        a.free(p).unwrap();
        assert_eq!(a.used(), 0);
    }
}
