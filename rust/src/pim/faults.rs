//! Deterministic fault injection and recovery (DESIGN.md §18).
//!
//! Real UPMEM deployments see transient DPU launch failures, rank
//! stalls, and corrupted host<->PIM transfers; the PIM adoption
//! literature names reliability as the gap between prototypes and
//! production.  This module models those failures *deterministically*:
//! a seeded [`FaultSpec`] drives a per-job [`FaultSession`] whose
//! injection draws come from the crate's own [`Prng`], so the same seed
//! always produces the same fault sequence, the same retry count, and —
//! because injection never touches functional bank state — the same
//! final bits as the fault-free run whenever recovery succeeds.
//!
//! Detection is modeled faithfully: transfers carry FNV-1a checksums
//! ([`fnv1a`]; a single flipped bit always changes the digest, see
//! [`FaultSession::bitflip_detected`]) and kernel launches report a
//! status word through `ExecBackend::launch_status`.  Recovery is
//! bounded retry with exponential backoff, charged in virtual time on
//! the `Timeline` retry lane; budget exhaustion surfaces as
//! [`crate::error::Error::Fault`] carrying the op's fault history — the
//! scheduler's dead-letter path.  With no spec installed every hook is
//! a no-op and every path stays bit- and timeline-identical to a build
//! without this module.

use crate::error::{Error, Result};
use crate::util::prng::Prng;

/// FNV-1a 64-bit offset basis.
const FNV_OFFSET: u64 = 0xcbf29ce484222325;
/// FNV-1a 64-bit prime (odd, so the per-byte multiply is injective
/// mod 2^64 — the property the detection guarantee rests on).
const FNV_PRIME: u64 = 0x100000001b3;

/// FNV-1a over a byte slice — the per-transfer checksum.  Each step is
/// `h = (h ^ byte) * prime`; xor with a fixed byte and multiplication
/// by an odd constant are both bijections on `u64`, so two payloads
/// differing in exactly one byte can never collide: a single bit flip
/// is always detected.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Order-independent checksum of a per-DPU row set: XOR of per-row
/// FNV-1a digests (each salted with the row length), so rank-sharded
/// backends that marshal rows in any worker order still agree on the
/// transfer's checksum.
pub fn checksum_rows(rows: &[Vec<u8>]) -> u64 {
    rows.iter().fold(0u64, |acc, r| {
        acc ^ fnv1a(r).wrapping_mul(FNV_PRIME) ^ r.len() as u64
    })
}

/// Failure class a fault plan can inject.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// A kernel launch that never completes: the backend's status word
    /// comes back non-zero and the launch must be reissued.
    LaunchFail,
    /// A per-rank transfer engine stall: the command times out and the
    /// transfer must be reissued.
    TransferStall,
    /// Bit-flip corruption in flight: the FNV checksum mismatches and
    /// the payload must be resent (bank state keeps the good bytes —
    /// the model resends the original payload, which is exactly why
    /// successful recovery is bit-identical by construction).
    BitFlip,
}

impl std::fmt::Display for FaultKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            FaultKind::LaunchFail => "launch-fail",
            FaultKind::TransferStall => "transfer-stall",
            FaultKind::BitFlip => "bit-flip",
        })
    }
}

/// One injected fault, recorded for attribution (the dead-letter
/// message and `--explain` surface these).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultEvent {
    pub kind: FaultKind,
    /// Rank the fault was attributed to.
    pub rank: usize,
    /// Virtual time on the injecting lane when the fault hit.
    pub at_s: f64,
    /// Retry attempt that absorbed it (1 = first reissue).
    pub attempt: u32,
}

impl std::fmt::Display for FaultEvent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} on rank {} at {:.3} ms (attempt {})",
            self.kind,
            self.rank,
            self.at_s * 1e3,
            self.attempt
        )
    }
}

/// The declared fault plan: what to inject, seeded so the whole
/// sequence replays bit-identically.  Parsed from `--faults` /
/// `SIMPLEPIM_FAULTS` (`off`, or `seed=7,rate=0.05[,dead-rank=1]
/// [,dead-at=0.002]`).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    /// Seed of the injection stream (forked per job, so racing batch
    /// workers cannot perturb each other's draws).
    pub seed: u64,
    /// Per-operation fault probability in `[0, 1]`.
    pub rate: f64,
    /// A rank declared dead: the scheduler quarantines every partition
    /// covering it and re-admits their jobs onto healthy ranks.
    pub dead_rank: Option<usize>,
    /// Virtual-time point at which `dead_rank` dies (0 = before any
    /// job starts).
    pub dead_at_s: f64,
}

impl FaultSpec {
    /// Parse a fault-plan declaration.  `src` names the flag or env var
    /// for diagnostics; `off` (and the empty string) disable injection.
    /// Unknown keys, garbage numbers, and rates outside `[0, 1]` are
    /// hard config errors naming the source and value — the house rule:
    /// a typo must never silently run fault-free.
    pub fn parse(src: &str, v: &str) -> Result<Option<FaultSpec>> {
        let v = v.trim();
        if v.is_empty() || v == "off" {
            return Ok(None);
        }
        let mut spec = FaultSpec { seed: 0, rate: 0.0, dead_rank: None, dead_at_s: 0.0 };
        let mut saw_seed = false;
        for part in v.split(',') {
            let (key, val) = part.split_once('=').ok_or_else(|| {
                Error::Config(format!(
                    "{src} expects off or key=value pairs (seed=,rate=,dead-rank=,dead-at=), \
                     got `{part}` in `{v}`"
                ))
            })?;
            match key.trim() {
                "seed" => {
                    spec.seed = val.trim().parse().map_err(|_| {
                        Error::Config(format!("{src}: seed expects an integer, got `{val}`"))
                    })?;
                    saw_seed = true;
                }
                "rate" => {
                    spec.rate = match val.trim().parse::<f64>() {
                        Ok(r) if r.is_finite() && (0.0..=1.0).contains(&r) => r,
                        _ => {
                            return Err(Error::Config(format!(
                                "{src}: rate expects a probability in [0, 1], got `{val}`"
                            )))
                        }
                    };
                }
                "dead-rank" => {
                    spec.dead_rank = Some(val.trim().parse().map_err(|_| {
                        Error::Config(format!(
                            "{src}: dead-rank expects a rank index, got `{val}`"
                        ))
                    })?);
                }
                "dead-at" => {
                    spec.dead_at_s = match val.trim().parse::<f64>() {
                        Ok(t) if t.is_finite() && t >= 0.0 => t,
                        _ => {
                            return Err(Error::Config(format!(
                                "{src}: dead-at expects non-negative seconds, got `{val}`"
                            )))
                        }
                    };
                }
                other => {
                    return Err(Error::Config(format!(
                        "{src}: unknown fault key `{other}` in `{v}` \
                         (expected seed, rate, dead-rank, dead-at)"
                    )))
                }
            }
        }
        if !saw_seed {
            return Err(Error::Config(format!(
                "{src}: a fault plan must declare seed= (determinism is the contract), \
                 got `{v}`"
            )));
        }
        Ok(Some(spec))
    }

    /// Render back to the canonical `key=value` spelling (the `info`
    /// provenance table and report headers print this).
    pub fn render(&self) -> String {
        let mut s = format!("seed={},rate={}", self.seed, self.rate);
        if let Some(r) = self.dead_rank {
            s.push_str(&format!(",dead-rank={r}"));
        }
        if self.dead_at_s > 0.0 {
            s.push_str(&format!(",dead-at={}", self.dead_at_s));
        }
        s
    }
}

/// How faults are recovered: bounded retry with exponential backoff
/// (charged on the `Timeline` retry lane) and optional rank
/// quarantine.  Configured per service/queue; `SIMPLEPIM_FAULT_RETRIES`
/// and `SIMPLEPIM_FAULT_BACKOFF` set the defaults.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecoveryPolicy {
    /// Reissues allowed per operation before it dead-letters.
    pub retry_budget: u32,
    /// First backoff in modeled seconds; attempt `k` waits
    /// `backoff_base_s * 2^(k-1)`.
    pub backoff_base_s: f64,
    /// Whether a declared dead rank quarantines its partitions (off =
    /// jobs on the dead rank dead-letter instead of migrating).
    pub quarantine: bool,
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        RecoveryPolicy { retry_budget: 3, backoff_base_s: 1e-4, quarantine: true }
    }
}

/// One lane's live injection stream: the seeded draw state plus the
/// fault history it has produced.  Forked from the plan per job
/// (`FaultSession::new(spec, salt)` with the job's submission index as
/// salt), so the sequence a job sees depends only on the plan seed and
/// its own index — never on which worker thread or partition ran it.
#[derive(Debug, Clone)]
pub struct FaultSession {
    prng: Prng,
    rate: f64,
    /// Every fault injected into this lane, in injection order.
    pub events: Vec<FaultEvent>,
}

impl FaultSession {
    pub fn new(spec: &FaultSpec, salt: u64) -> FaultSession {
        // splitmix-style spread of (seed, salt) so per-job streams are
        // independent; same constant as `Prng::fork`.
        let seed = spec.seed ^ salt.wrapping_mul(0x9E3779B97F4A7C15);
        FaultSession { prng: Prng::new(seed), rate: spec.rate, events: Vec::new() }
    }

    /// Draw the launch-site injection decision: `Some((rank, code))`
    /// when this launch faults, attributed to a rank of the `n_ranks`
    /// the launch spans, with a non-zero device status code.
    pub fn draw_launch(&mut self, n_ranks: usize) -> Option<(usize, u32)> {
        if !self.prng.chance(self.rate) {
            return None;
        }
        let rank = self.prng.below(n_ranks.max(1) as u64) as usize;
        let code = (self.prng.next_u64() as u32) | 1; // never the OK word
        Some((rank, code))
    }

    /// Draw the transfer-site injection decision: a stall or an
    /// in-flight bit flip on one of `n_ranks` engines.
    pub fn draw_transfer(&mut self, n_ranks: usize) -> Option<(FaultKind, usize)> {
        if !self.prng.chance(self.rate) {
            return None;
        }
        let kind = if self.prng.chance(0.5) {
            FaultKind::TransferStall
        } else {
            FaultKind::BitFlip
        };
        let rank = self.prng.below(n_ranks.max(1) as u64) as usize;
        Some((kind, rank))
    }

    /// Model the checksum check that catches an injected bit flip:
    /// corrupt one prng-chosen bit of a copy of `payload` and compare
    /// FNV digests.  Always `true` for non-empty payloads (see
    /// [`fnv1a`]) — the guarantee that detection, and therefore
    /// recovery, can never miss a single-bit corruption.
    pub fn bitflip_detected(&mut self, payload: &[u8]) -> bool {
        if payload.is_empty() {
            return true;
        }
        let good = fnv1a(payload);
        let bit = self.prng.below(payload.len() as u64 * 8);
        let mut corrupt = payload.to_vec();
        corrupt[(bit / 8) as usize] ^= 1 << (bit % 8);
        fnv1a(&corrupt) != good
    }

    /// Record one absorbed fault.
    pub fn record(&mut self, kind: FaultKind, rank: usize, at_s: f64, attempt: u32) {
        self.events.push(FaultEvent { kind, rank, at_s, attempt });
    }

    /// Format the session's fault history for dead-letter attribution.
    pub fn history(&self) -> String {
        let parts: Vec<String> = self.events.iter().map(|e| e.to_string()).collect();
        parts.join("; ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_detects_every_single_bit_flip() {
        let payload: Vec<u8> = (0..64u8).collect();
        let good = fnv1a(&payload);
        for byte in 0..payload.len() {
            for bit in 0..8 {
                let mut p = payload.clone();
                p[byte] ^= 1 << bit;
                assert_ne!(fnv1a(&p), good, "flip at {byte}:{bit} must change the digest");
            }
        }
    }

    #[test]
    fn row_checksum_is_shard_order_invariant() {
        let rows: Vec<Vec<u8>> = (0..5).map(|d| vec![d as u8; 16]).collect();
        let mut shuffled = rows.clone();
        shuffled.swap(0, 4);
        shuffled.swap(1, 3);
        assert_eq!(checksum_rows(&rows), checksum_rows(&shuffled));
        let mut corrupted = rows.clone();
        corrupted[2][7] ^= 0x10;
        assert_ne!(checksum_rows(&rows), checksum_rows(&corrupted));
    }

    #[test]
    fn spec_parses_and_renders() {
        let s = FaultSpec::parse("--faults", "seed=7,rate=0.05").unwrap().unwrap();
        assert_eq!(s.seed, 7);
        assert_eq!(s.rate, 0.05);
        assert_eq!(s.dead_rank, None);
        let s = FaultSpec::parse("--faults", "seed=3,rate=1,dead-rank=2,dead-at=0.5")
            .unwrap()
            .unwrap();
        assert_eq!(s.dead_rank, Some(2));
        assert_eq!(s.dead_at_s, 0.5);
        assert_eq!(s.render(), "seed=3,rate=1,dead-rank=2,dead-at=0.5");
        assert!(FaultSpec::parse("--faults", "off").unwrap().is_none());
        assert!(FaultSpec::parse("--faults", "").unwrap().is_none());
    }

    #[test]
    fn spec_rejects_garbage_with_the_source() {
        for bad in ["rate=0.5", "seed=x,rate=0.1", "seed=1,rate=2", "seed=1,bogus=3", "seed"] {
            let err = FaultSpec::parse("SIMPLEPIM_FAULTS", bad).unwrap_err();
            assert!(matches!(err, Error::Config(_)), "{bad}: {err}");
            assert!(err.to_string().contains("SIMPLEPIM_FAULTS"), "{bad}: {err}");
        }
    }

    #[test]
    fn sessions_replay_bit_identically_from_a_seed() {
        let spec = FaultSpec { seed: 41, rate: 0.5, dead_rank: None, dead_at_s: 0.0 };
        let mut a = FaultSession::new(&spec, 3);
        let mut b = FaultSession::new(&spec, 3);
        for _ in 0..256 {
            assert_eq!(a.draw_transfer(8), b.draw_transfer(8));
            assert_eq!(a.draw_launch(8), b.draw_launch(8));
        }
        // A different salt (another job) moves the stream; compare a
        // 64-draw fold so a chance single-draw collision cannot flake.
        let fold = |salt: u64| {
            let mut s = FaultSession::new(&spec, salt);
            (0..64).fold(0u64, |acc, i| {
                acc ^ s.draw_launch(8).map(|(r, c)| (r as u64) << 32 | c as u64).unwrap_or(i)
            })
        };
        assert_ne!(fold(1), fold(2));
    }

    #[test]
    fn bitflip_detection_never_misses() {
        let spec = FaultSpec { seed: 9, rate: 1.0, dead_rank: None, dead_at_s: 0.0 };
        let mut s = FaultSession::new(&spec, 0);
        let payload: Vec<u8> = (0..200u8).cycle().take(4096).collect();
        for _ in 0..100 {
            assert!(s.bitflip_detected(&payload));
        }
        assert!(s.bitflip_detected(&[]), "empty payloads are trivially clean");
    }

    #[test]
    fn rate_one_always_faults_rate_zero_never() {
        let hot = FaultSpec { seed: 1, rate: 1.0, dead_rank: None, dead_at_s: 0.0 };
        let mut s = FaultSession::new(&hot, 0);
        for _ in 0..64 {
            assert!(s.draw_launch(4).is_some());
            assert!(s.draw_transfer(4).is_some());
        }
        let cold = FaultSpec { seed: 1, rate: 0.0, dead_rank: None, dead_at_s: 0.0 };
        let mut s = FaultSession::new(&cold, 0);
        for _ in 0..64 {
            assert!(s.draw_launch(4).is_none());
            assert!(s.draw_transfer(4).is_none());
        }
    }
}
