//! A thin UPMEM-SDK-like device API for *hand-written* PIM kernels.
//!
//! The paper's baselines (PrIM, pim-ml) are written directly against the
//! UPMEM SDK: explicit `mem_alloc` of WRAM buffers, explicit
//! `mram_read`/`mram_write` batching with the 8-byte/2,048-byte rules,
//! manual per-tasklet address arithmetic, barriers.  The baseline
//! implementations in `workloads/baseline/` are written against *this*
//! module so that (a) they are functionally executed byte-for-byte like
//! the originals, (b) their DMA call pattern is *measured*, not assumed
//! — a baseline that issues fixed-size or per-element transfers pays
//! exactly for the calls it makes — and (c) the lines-of-code comparison
//! in Table 1 counts real, runnable low-level code.

use crate::error::{Error, Result};

use super::config::PimConfig;
use super::dma;
use super::device::PimMachine;

/// WRAM pointer: a byte offset into the 64 KB scratchpad.
pub type WramPtr = usize;

/// Per-DPU scratchpad with a bump heap (`mem_alloc`/`mem_reset`).
pub struct Wram {
    data: Vec<u8>,
    heap: usize,
}

impl std::fmt::Debug for Wram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // 64 KB of scratchpad bytes: render the shape, not the data.
        f.debug_struct("Wram")
            .field("bytes", &self.data.len())
            .field("heap", &self.heap)
            .finish()
    }
}

impl Wram {
    pub fn new(cfg: &PimConfig) -> Self {
        Wram { data: vec![0u8; cfg.wram_bytes as usize], heap: 0 }
    }

    /// UPMEM `mem_reset`: drop the whole heap.
    pub fn mem_reset(&mut self) {
        self.heap = 0;
    }

    /// UPMEM `mem_alloc`: bump-allocate `bytes` (8-byte aligned).
    pub fn mem_alloc(&mut self, bytes: usize) -> Result<WramPtr> {
        let aligned = crate::util::round_up(bytes as u64, 8) as usize;
        if self.heap + aligned > self.data.len() {
            return Err(Error::Capacity(format!(
                "WRAM heap exhausted: {} + {} > {}",
                self.heap,
                aligned,
                self.data.len()
            )));
        }
        let ptr = self.heap;
        self.heap += aligned;
        Ok(ptr)
    }

    pub fn slice(&self, ptr: WramPtr, len: usize) -> &[u8] {
        &self.data[ptr..ptr + len]
    }

    pub fn slice_mut(&mut self, ptr: WramPtr, len: usize) -> &mut [u8] {
        &mut self.data[ptr..ptr + len]
    }

    /// Typed view of a WRAM buffer as i32 (UPMEM kernels cast freely).
    pub fn as_i32(&self, ptr: WramPtr, elems: usize) -> Vec<i32> {
        self.slice(ptr, elems * 4)
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    pub fn write_i32(&mut self, ptr: WramPtr, vals: &[i32]) {
        let dst = self.slice_mut(ptr, vals.len() * 4);
        for (i, v) in vals.iter().enumerate() {
            dst[i * 4..i * 4 + 4].copy_from_slice(&v.to_le_bytes());
        }
    }
}

/// DMA accounting for one kernel execution on one DPU.
#[derive(Debug, Clone, Copy, Default)]
pub struct DmaLog {
    pub transfers: u64,
    pub bytes: u64,
    pub cycles: f64,
}

/// Execution context handed to a hand-written per-DPU kernel: the DPU's
/// MRAM bank plus its WRAM, with checked, *metered* DMA.
pub struct DpuCtx<'m> {
    machine: &'m mut PimMachine,
    pub dpu: usize,
    pub wram: Wram,
    pub dma: DmaLog,
}

impl std::fmt::Debug for DpuCtx<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DpuCtx")
            .field("dpu", &self.dpu)
            .field("wram", &self.wram)
            .field("dma", &self.dma)
            .finish_non_exhaustive()
    }
}

impl<'m> DpuCtx<'m> {
    pub fn new(machine: &'m mut PimMachine, dpu: usize) -> Self {
        let wram = Wram::new(&machine.cfg.clone());
        DpuCtx { machine, dpu, wram, dma: DmaLog::default() }
    }

    pub fn cfg(&self) -> &PimConfig {
        &self.machine.cfg
    }

    fn meter(&mut self, bytes: u64) {
        self.dma.transfers += 1;
        self.dma.bytes += bytes;
        self.dma.cycles += dma::transfer_cycles(&self.machine.cfg, bytes);
    }

    /// UPMEM `mram_read`: MRAM -> WRAM, alignment/size checked + metered.
    pub fn mram_read(&mut self, mram_addr: u64, wram_ptr: WramPtr, bytes: u64) -> Result<()> {
        dma::check_transfer(&self.machine.cfg, mram_addr, bytes)?;
        let data = self.machine.read_bytes(self.dpu, mram_addr, bytes)?;
        self.wram.slice_mut(wram_ptr, bytes as usize).copy_from_slice(&data);
        self.meter(bytes);
        Ok(())
    }

    /// UPMEM `mram_write`: WRAM -> MRAM, alignment/size checked + metered.
    pub fn mram_write(&mut self, wram_ptr: WramPtr, mram_addr: u64, bytes: u64) -> Result<()> {
        dma::check_transfer(&self.machine.cfg, mram_addr, bytes)?;
        let data = self.wram.slice(wram_ptr, bytes as usize).to_vec();
        self.machine.write_bytes(self.dpu, mram_addr, &data)?;
        self.meter(bytes);
        Ok(())
    }
}

/// Run a hand-written kernel on every DPU; returns the per-DPU DMA logs
/// so the caller can convert the *measured* DMA pattern plus its declared
/// instruction mix into kernel time.
pub fn launch_on_all<F>(machine: &mut PimMachine, mut kernel: F) -> Result<Vec<DmaLog>>
where
    F: FnMut(&mut DpuCtx<'_>) -> Result<()>,
{
    let n = machine.n_dpus();
    let mut logs = Vec::with_capacity(n);
    for dpu in 0..n {
        let mut ctx = DpuCtx::new(machine, dpu);
        kernel(&mut ctx)?;
        logs.push(ctx.dma);
    }
    Ok(logs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pim::config::PimConfig;

    #[test]
    fn wram_alloc_and_reset() {
        let cfg = PimConfig::tiny(1);
        let mut w = Wram::new(&cfg);
        let a = w.mem_alloc(100).unwrap();
        let b = w.mem_alloc(100).unwrap();
        assert_eq!(a, 0);
        assert_eq!(b, 104); // 8-byte aligned bump
        w.mem_reset();
        assert_eq!(w.mem_alloc(8).unwrap(), 0);
    }

    #[test]
    fn wram_exhaustion_errors() {
        let cfg = PimConfig::tiny(1);
        let mut w = Wram::new(&cfg);
        assert!(w.mem_alloc(65 * 1024).is_err());
    }

    #[test]
    fn i32_views_roundtrip() {
        let cfg = PimConfig::tiny(1);
        let mut w = Wram::new(&cfg);
        let p = w.mem_alloc(16).unwrap();
        w.write_i32(p, &[1, -2, 3, -4]);
        assert_eq!(w.as_i32(p, 4), vec![1, -2, 3, -4]);
    }

    #[test]
    fn dma_is_checked_and_metered() {
        let mut m = PimMachine::new(PimConfig::tiny(2));
        let addr = m.alloc(4096).unwrap();
        m.write_bytes(0, addr, &[5u8; 64]).unwrap();
        let mut ctx = DpuCtx::new(&mut m, 0);
        let buf = ctx.wram.mem_alloc(2048).unwrap();
        ctx.mram_read(addr, buf, 64).unwrap();
        assert_eq!(ctx.wram.slice(buf, 64), &[5u8; 64]);
        assert_eq!(ctx.dma.transfers, 1);
        assert_eq!(ctx.dma.bytes, 64);
        assert!(ctx.dma.cycles > 0.0);
        // Constraint violations surface as errors, like real hardware
        // faults (which in practice hang or corrupt).
        assert!(ctx.mram_read(addr + 4, buf, 64).is_err());
        assert!(ctx.mram_read(addr, buf, 4096).is_err());
    }

    #[test]
    fn launch_visits_every_dpu() {
        let mut m = PimMachine::new(PimConfig::tiny(4));
        let addr = m.alloc(64).unwrap();
        for d in 0..4 {
            m.write_bytes(d, addr, &[d as u8; 8]).unwrap();
        }
        let logs = launch_on_all(&mut m, |ctx| {
            let p = ctx.wram.mem_alloc(8)?;
            ctx.mram_read(addr, p, 8)?;
            assert_eq!(ctx.wram.slice(p, 8)[0], ctx.dpu as u8);
            Ok(())
        })
        .unwrap();
        assert_eq!(logs.len(), 4);
        assert!(logs.iter().all(|l| l.transfers == 1));
    }
}
