//! DPU instruction cost table.
//!
//! The UPMEM DPU is an in-order scalar core: with a full pipeline it
//! retires one instruction per cycle for simple integer ops, but 32-bit
//! multiply/divide are emulated by a hardware loop (up to 32 cycles, §2
//! of the paper) and floating point is emulated in software (tens to
//! ~2,000 cycles [26]).  These per-op *issue-slot* costs are what the
//! pipeline model multiplies out; they are the mechanism behind the
//! paper's strength-reduction optimization (§4.3.1) and the
//! integer-quantization of the ML workloads (§5.1).

/// Instruction classes the timing model distinguishes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Op {
    /// Integer add/sub/logic/compare/move — single issue slot.
    IAlu,
    /// Shift by constant or register — single slot (the strength-reduced
    /// replacement for multiplies).
    Shift,
    /// 8/16-bit multiply (hardware-assisted, short loop).
    IMulShort,
    /// Full 32-bit multiply (emulated loop, up to 32 slots).
    IMul32,
    /// 32-bit divide (emulated, worst case).
    IDiv32,
    /// WRAM load.
    Load,
    /// WRAM store.
    Store,
    /// Conditional branch (includes the compare fused before it).
    Branch,
    /// Function call + return overhead (register save/restore).
    CallRet,
    /// Software-emulated FP add.
    FAdd,
    /// Software-emulated FP multiply.
    FMul,
    /// Software-emulated FP divide (paper: up to ~2,000 cycles).
    FDiv,
    /// Mutex acquire+release pair (shared-accumulator reduction).
    LockPair,
    /// Barrier wait (per participating tasklet).
    Barrier,
}

/// Issue-slot cost of one instruction of class `op`.
pub fn slots(op: Op) -> u64 {
    match op {
        Op::IAlu => 1,
        Op::Shift => 1,
        Op::IMulShort => 4,
        Op::IMul32 => 32,
        Op::IDiv32 => 48,
        Op::Load => 1,
        Op::Store => 1,
        Op::Branch => 1,
        Op::CallRet => 12,
        Op::FAdd => 60,
        Op::FMul => 110,
        Op::FDiv => 2000,
        Op::LockPair => 5,
        Op::Barrier => 32,
    }
}

/// A weighted instruction mix — typically "per input element of the
/// inner loop".  Costs are accumulated in issue slots; the pipeline model
/// converts slots to cycles given the tasklet count.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct InstrMix {
    pub ialu: f64,
    pub shift: f64,
    pub imul_short: f64,
    pub imul32: f64,
    pub idiv32: f64,
    pub load: f64,
    pub store: f64,
    pub branch: f64,
    pub call_ret: f64,
    pub fadd: f64,
    pub fmul: f64,
    pub fdiv: f64,
    pub lock_pair: f64,
    pub barrier: f64,
}

impl InstrMix {
    /// Total issue slots for this mix.
    pub fn total_slots(&self) -> f64 {
        self.ialu * slots(Op::IAlu) as f64
            + self.shift * slots(Op::Shift) as f64
            + self.imul_short * slots(Op::IMulShort) as f64
            + self.imul32 * slots(Op::IMul32) as f64
            + self.idiv32 * slots(Op::IDiv32) as f64
            + self.load * slots(Op::Load) as f64
            + self.store * slots(Op::Store) as f64
            + self.branch * slots(Op::Branch) as f64
            + self.call_ret * slots(Op::CallRet) as f64
            + self.fadd * slots(Op::FAdd) as f64
            + self.fmul * slots(Op::FMul) as f64
            + self.fdiv * slots(Op::FDiv) as f64
            + self.lock_pair * slots(Op::LockPair) as f64
            + self.barrier * slots(Op::Barrier) as f64
    }

    /// Component-wise sum of two mixes.
    pub fn plus(&self, other: &InstrMix) -> InstrMix {
        InstrMix {
            ialu: self.ialu + other.ialu,
            shift: self.shift + other.shift,
            imul_short: self.imul_short + other.imul_short,
            imul32: self.imul32 + other.imul32,
            idiv32: self.idiv32 + other.idiv32,
            load: self.load + other.load,
            store: self.store + other.store,
            branch: self.branch + other.branch,
            call_ret: self.call_ret + other.call_ret,
            fadd: self.fadd + other.fadd,
            fmul: self.fmul + other.fmul,
            fdiv: self.fdiv + other.fdiv,
            lock_pair: self.lock_pair + other.lock_pair,
            barrier: self.barrier + other.barrier,
        }
    }

    /// Scale every count by `k` (e.g. per-element mix -> per-batch mix).
    pub fn scaled(&self, k: f64) -> InstrMix {
        InstrMix {
            ialu: self.ialu * k,
            shift: self.shift * k,
            imul_short: self.imul_short * k,
            imul32: self.imul32 * k,
            idiv32: self.idiv32 * k,
            load: self.load * k,
            store: self.store * k,
            branch: self.branch * k,
            call_ret: self.call_ret * k,
            fadd: self.fadd * k,
            fmul: self.fmul * k,
            fdiv: self.fdiv * k,
            lock_pair: self.lock_pair * k,
            barrier: self.barrier * k,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alu_is_single_slot_mul_is_expensive() {
        assert_eq!(slots(Op::IAlu), 1);
        assert!(slots(Op::IMul32) >= 16);
        assert!(slots(Op::FDiv) > slots(Op::FMul));
    }

    #[test]
    fn mix_totals() {
        let m = InstrMix { ialu: 2.0, imul32: 1.0, ..Default::default() };
        assert_eq!(m.total_slots(), 2.0 + 32.0);
    }

    #[test]
    fn mix_plus_and_scale() {
        let a = InstrMix { load: 1.0, ..Default::default() };
        let b = InstrMix { store: 2.0, ..Default::default() };
        let c = a.plus(&b).scaled(3.0);
        assert_eq!(c.load, 3.0);
        assert_eq!(c.store, 6.0);
        assert_eq!(c.total_slots(), 9.0);
    }

    #[test]
    fn strength_reduction_saves_slots() {
        // A multiply-based address computation vs the shifted one: this
        // inequality is the entire basis of paper §4.3 optimization 1.
        let with_mul = InstrMix { imul32: 1.0, ..Default::default() };
        let with_shift = InstrMix { shift: 1.0, ..Default::default() };
        assert!(with_mul.total_slots() > 8.0 * with_shift.total_slots());
    }
}
