//! Host<->PIM transfer model (the DIMM bus side).
//!
//! UPMEM exposes *serial* commands (one DPU at a time) and *parallel*
//! commands (same-sized buffers pushed to / pulled from many DPUs at
//! once, rank by rank).  Parallel bandwidth grows with the number of
//! ranks and "can be orders of magnitude higher than the serial transfer
//! bandwidth" (paper §4.1).  SimplePIM always arranges data so the
//! parallel commands are usable; hand-written code that falls back to
//! serial transfers pays for it here.

use super::config::PimConfig;

/// Which transfer command a communication step uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum XferKind {
    /// `dpu_push_xfer`-style parallel transfer: every DPU sends/receives
    /// the same number of bytes simultaneously.
    Parallel,
    /// Per-DPU serial copy.
    Serial,
    /// Broadcast: the same buffer goes to every DPU (parallel command,
    /// bytes counted once per rank on the bus).
    Broadcast,
}

/// Seconds to move `bytes_per_dpu` bytes to/from each of `n_dpus` DPUs.
pub fn transfer_seconds(
    cfg: &PimConfig,
    kind: XferKind,
    n_dpus: usize,
    bytes_per_dpu: u64,
) -> f64 {
    if n_dpus == 0 || bytes_per_dpu == 0 {
        return 0.0;
    }
    let ranks = n_dpus.div_ceil(cfg.rank_dpus());
    let ranks_used = ranks as f64;
    // Topology-aware aggregate bandwidth (DESIGN.md §15): one engine
    // per rank in parallel, capped by the channel buses the transfer
    // spreads across and by the global ceiling.  Flat configs resolve
    // to `rank_dpus == dpus_per_rank` and a single channel whose cap
    // equals the ceiling — the pre-topology number, bit for bit.
    let bw = (ranks_used * cfg.xfer_rank_bw)
        .min(cfg.channels_used(ranks) as f64 * cfg.xfer_channel_bw)
        .min(cfg.xfer_bw_ceiling);
    match kind {
        XferKind::Parallel => {
            let total = n_dpus as f64 * bytes_per_dpu as f64;
            cfg.xfer_latency_s + total / bw
        }
        XferKind::Serial => {
            // One command per DPU, each at single-DPU bandwidth.
            n_dpus as f64 * (cfg.xfer_latency_s + bytes_per_dpu as f64 / cfg.xfer_serial_bw)
        }
        XferKind::Broadcast => {
            // The buffer is replicated on the bus once per rank in
            // parallel: time is governed by one rank's share.
            cfg.xfer_latency_s + (ranks_used * bytes_per_dpu as f64) / bw
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> PimConfig {
        PimConfig::upmem(608) // 10 ranks
    }

    #[test]
    fn parallel_beats_serial() {
        // Paper §4.1: parallel command bandwidth grows with ranks and
        // leaves per-DPU serial copies far behind.
        let c = cfg();
        let p = transfer_seconds(&c, XferKind::Parallel, 608, 1 << 20);
        let s = transfer_seconds(&c, XferKind::Serial, 608, 1 << 20);
        assert!(s > 5.0 * p, "serial should be much slower: {s} vs {p}");
    }

    #[test]
    fn parallel_scales_with_ranks() {
        let small = PimConfig::upmem(64); // 1 rank
        let big = PimConfig::upmem(640); // 10 ranks
        let per_dpu = 1u64 << 20;
        let t_small = transfer_seconds(&small, XferKind::Parallel, 64, per_dpu);
        let t_big = transfer_seconds(&big, XferKind::Parallel, 640, per_dpu);
        // 10x the data across 10x the ranks => roughly the same time.
        assert!((t_big / t_small) < 1.3);
    }

    #[test]
    fn broadcast_cheaper_than_scatter_of_same_total() {
        let c = cfg();
        // Broadcasting 1 MB to all DPUs moves ~1 MB per *rank*, while
        // scattering 1 MB per DPU moves 1 MB per *DPU*.
        let b = transfer_seconds(&c, XferKind::Broadcast, 608, 1 << 20);
        let p = transfer_seconds(&c, XferKind::Parallel, 608, 1 << 20);
        assert!(b < p);
    }

    #[test]
    fn zero_work_is_free() {
        let c = cfg();
        assert_eq!(transfer_seconds(&c, XferKind::Parallel, 0, 1024), 0.0);
        assert_eq!(transfer_seconds(&c, XferKind::Parallel, 8, 0), 0.0);
    }

    #[test]
    fn explicit_topology_multiplies_rank_engines() {
        // 32 DPUs flat = one partial rank; as 2x4 the same DPUs sit
        // behind 8 rank engines, so the same scatter models ~8x faster
        // (the fixed command latency is the only non-scaling term).
        let flat = PimConfig::upmem(32);
        let topo = PimConfig::upmem(32).with_topology(2, 4).unwrap();
        let per_dpu = 1u64 << 20;
        let t_flat = transfer_seconds(&flat, XferKind::Parallel, 32, per_dpu);
        let t_topo = transfer_seconds(&topo, XferKind::Parallel, 32, per_dpu);
        let flat_stream = t_flat - flat.xfer_latency_s;
        let topo_stream = t_topo - topo.xfer_latency_s;
        assert!((flat_stream / topo_stream - 8.0).abs() < 1e-9);

        // Touching only 4 DPUs uses a single rank engine of the tree:
        // same bandwidth as a flat partial rank.
        let t_part = transfer_seconds(&topo, XferKind::Parallel, 4, per_dpu);
        let t_ref = transfer_seconds(&flat, XferKind::Parallel, 4, per_dpu);
        assert_eq!(t_part, t_ref);
    }

    #[test]
    fn flat_1x1_topology_is_bit_identical() {
        let flat = PimConfig::upmem(608);
        let one = PimConfig::upmem(608).with_topology(1, 1).unwrap();
        for kind in [XferKind::Parallel, XferKind::Serial, XferKind::Broadcast] {
            for n in [1usize, 63, 64, 65, 608] {
                for bytes in [8u64, 4096, 1 << 20] {
                    assert_eq!(
                        transfer_seconds(&flat, kind, n, bytes),
                        transfer_seconds(&one, kind, n, bytes),
                        "{kind:?} n={n} bytes={bytes}"
                    );
                }
            }
        }
    }

    #[test]
    fn lowered_channel_cap_binds_transfers() {
        let mut topo = PimConfig::upmem(32).with_topology(2, 4).unwrap();
        topo.xfer_channel_bw = 700e6; // 2 ranks' worth per channel
        let t = transfer_seconds(&topo, XferKind::Parallel, 32, 1 << 20);
        let total = 32.0 * (1u64 << 20) as f64;
        // 8 ranks would give 2.8 GB/s, but 2 channels x 700 MB/s cap it.
        let want = topo.xfer_latency_s + total / 1.4e9;
        assert!((t - want).abs() < 1e-12);
    }

    #[test]
    fn ceiling_binds_at_scale() {
        let big = PimConfig::upmem(64 * 64); // 64 ranks >> ceiling
        let t = transfer_seconds(&big, XferKind::Parallel, big.n_dpus, 1 << 20);
        let total = big.n_dpus as f64 * (1u64 << 20) as f64;
        let floor = total / big.xfer_bw_ceiling;
        assert!(t >= floor);
    }
}
