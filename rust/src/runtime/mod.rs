//! The AOT runtime: manifest-driven loading and PJRT execution of the
//! `artifacts/*.hlo.txt` modules produced by `python/compile/aot.py`.
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Compilation happens once per artifact per process; the request path
//! only executes.

pub mod artifact;
pub mod executor;

pub use artifact::{ArtifactMeta, Manifest, TensorMeta};
pub use executor::{ExecStats, Runtime, TensorRef};
