//! Artifact manifest: what `python/compile/aot.py` produced.
//!
//! The manifest (`artifacts/manifest.json`) lists every AOT-compiled HLO
//! module with its workload family, fixed shapes, and parameters.  The
//! coordinator asks [`Manifest::select`] for the smallest variant whose
//! per-DPU capacity fits the live data; the transfer planner then pads
//! each DPU's slice up to that capacity with the workload's identity
//! element.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Shape+dtype of one executable input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorMeta {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorMeta {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .field("shape")?
            .as_arr()?
            .iter()
            .map(|d| d.as_usize())
            .collect::<Result<Vec<_>>>()?;
        let dtype = j.field("dtype")?.as_str()?.to_string();
        Ok(TensorMeta { shape, dtype })
    }
}

/// One AOT artifact (one `.hlo.txt` file).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub workload: String,
    pub params: BTreeMap<String, i64>,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
}

impl ArtifactMeta {
    /// The gang width `G` (DPUs per executable call).
    pub fn gang(&self) -> usize {
        self.params.get("gang").copied().unwrap_or(1) as usize
    }

    /// Per-DPU capacity `N` (elements or points).
    pub fn n(&self) -> usize {
        self.params.get("n").copied().unwrap_or(0) as usize
    }

    pub fn param(&self, key: &str) -> Result<i64> {
        self.params
            .get(key)
            .copied()
            .ok_or_else(|| Error::Artifact(format!("{}: missing param `{key}`", self.name)))
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} ({e}); run `make artifacts` first",
                path.display()
            ))
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (separated out for tests).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let doc = Json::parse(text)?;
        let mut artifacts = Vec::new();
        for a in doc.field("artifacts")?.as_arr()? {
            let mut params = BTreeMap::new();
            for (k, v) in a.field("params")?.as_obj()? {
                params.insert(k.clone(), v.as_i64()?);
            }
            artifacts.push(ArtifactMeta {
                name: a.field("name")?.as_str()?.to_string(),
                file: a.field("file")?.as_str()?.to_string(),
                workload: a.field("workload")?.as_str()?.to_string(),
                params,
                inputs: a
                    .field("inputs")?
                    .as_arr()?
                    .iter()
                    .map(TensorMeta::from_json)
                    .collect::<Result<Vec<_>>>()?,
                outputs: a
                    .field("outputs")?
                    .as_arr()?
                    .iter()
                    .map(TensorMeta::from_json)
                    .collect::<Result<Vec<_>>>()?,
            });
        }
        Ok(Manifest { dir, artifacts })
    }

    /// Find an artifact by exact name.
    pub fn by_name(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| Error::Artifact(format!("no artifact named `{name}`")))
    }

    /// Preferred execution engine: artifacts are AOT-compiled twice
    /// (DESIGN.md §8 Perf) — `pallas` (the L1 kernel under
    /// interpret=True: the hardware artifact, step-emulated on CPU) and
    /// `xla` (the same integer semantics lowered from plain jnp, which
    /// XLA-CPU fuses/vectorizes; ~50x faster to execute here).  Serving
    /// defaults to `xla`; set `SIMPLEPIM_ENGINE=pallas` to exercise the
    /// kernel lowering end-to-end.  Any other value aborts loudly
    /// (settings house rule): `SIMPLEPIM_ENGINE=palas` used to silently
    /// serve the xla path with the kernel lowering untested.
    pub fn preferred_engine() -> &'static str {
        crate::util::settings::engine_from_env().unwrap_or_else(|e| panic!("{e}"))
    }

    /// Select the smallest variant of `workload` with per-DPU capacity
    /// `>= min_n`, preferring the serving engine; falls back to the
    /// largest available (the executor will then be called repeatedly
    /// over chunks).
    pub fn select(&self, workload: &str, min_n: usize) -> Result<&ArtifactMeta> {
        let want_pallas = (Self::preferred_engine() == "pallas") as i64;
        let mut candidates: Vec<&ArtifactMeta> =
            self.artifacts.iter().filter(|a| a.workload == workload).collect();
        if candidates.is_empty() {
            return Err(Error::Artifact(format!("no artifacts for workload `{workload}`")));
        }
        // Engine preference first (manifests without the `pallas` param
        // predate dual lowering and are treated as engine-neutral),
        // then smallest fitting capacity.
        let preferred: Vec<&ArtifactMeta> = candidates
            .iter()
            .copied()
            .filter(|a| a.params.get("pallas").map(|&p| p == want_pallas).unwrap_or(true))
            .collect();
        if !preferred.is_empty() {
            candidates = preferred;
        }
        candidates.sort_by_key(|a| a.n());
        Ok(candidates
            .iter()
            .find(|a| a.n() >= min_n)
            .copied()
            .unwrap_or_else(|| candidates[candidates.len() - 1]))
    }

    /// Absolute path of an artifact's HLO text file.
    pub fn hlo_path(&self, a: &ArtifactMeta) -> PathBuf {
        self.dir.join(&a.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "artifacts": [
        {"name": "vecadd_g8_n8192", "file": "vecadd_g8_n8192.hlo.txt",
         "workload": "vecadd", "params": {"gang": 8, "n": 8192, "block": 2048},
         "inputs": [{"shape": [8, 8192], "dtype": "int32"},
                    {"shape": [8, 8192], "dtype": "int32"}],
         "outputs": [{"shape": [8, 8192], "dtype": "int32"}],
         "sha256_16": "00"},
        {"name": "vecadd_g8_n65536", "file": "vecadd_g8_n65536.hlo.txt",
         "workload": "vecadd", "params": {"gang": 8, "n": 65536, "block": 2048},
         "inputs": [{"shape": [8, 65536], "dtype": "int32"},
                    {"shape": [8, 65536], "dtype": "int32"}],
         "outputs": [{"shape": [8, 65536], "dtype": "int32"}],
         "sha256_16": "00"}
      ]
    }"#;

    fn manifest() -> Manifest {
        Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap()
    }

    #[test]
    fn parses_fields() {
        let m = manifest();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.by_name("vecadd_g8_n8192").unwrap();
        assert_eq!(a.gang(), 8);
        assert_eq!(a.n(), 8192);
        assert_eq!(a.inputs[0].elems(), 8 * 8192);
        assert_eq!(a.param("block").unwrap(), 2048);
        assert!(a.param("bins").is_err());
    }

    #[test]
    fn selects_smallest_fitting() {
        let m = manifest();
        assert_eq!(m.select("vecadd", 100).unwrap().n(), 8192);
        assert_eq!(m.select("vecadd", 8192).unwrap().n(), 8192);
        assert_eq!(m.select("vecadd", 8193).unwrap().n(), 65536);
        // Larger than anything: fall back to the largest variant.
        assert_eq!(m.select("vecadd", 1 << 20).unwrap().n(), 65536);
        assert!(m.select("nope", 1).is_err());
    }

    #[test]
    fn unknown_name_errors() {
        assert!(manifest().by_name("missing").is_err());
    }
}
