//! PJRT executor: load AOT HLO text, compile once, execute many times.
//!
//! This is the only place the `xla` crate is touched, and only when the
//! `pjrt` cargo feature is enabled (it needs a vendored xla-rs; this
//! offline environment cannot fetch one).  The pattern follows
//! /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.  Executables are cached per artifact
//! name, so each shape variant is compiled exactly once per process —
//! the request path only pays dispatch + data movement.
//!
//! Without the feature, [`Runtime::load`] returns a clear error and the
//! coordinator executes every kernel through the bit-identical host
//! goldens instead (`PimSystem::host_only` semantics); the two paths
//! are pinned to each other by the integration tests whenever artifacts
//! and the feature are both present.

use std::cell::RefCell;
use std::path::Path;

use crate::error::Result;

use super::artifact::Manifest;

/// Borrowed int32 tensor handed to the executor.
#[derive(Debug, Clone, Copy)]
pub struct TensorRef<'a> {
    pub data: &'a [i32],
    pub shape: &'a [usize],
}

impl<'a> TensorRef<'a> {
    pub fn new(data: &'a [i32], shape: &'a [usize]) -> Self {
        TensorRef { data, shape }
    }
}

/// Executor statistics for the perf pass (EXPERIMENTS.md §Perf).
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecStats {
    pub compiles: u64,
    pub calls: u64,
    pub literal_s: f64,
    pub execute_s: f64,
    pub readback_s: f64,
}

/// Default artifact directory: `$SIMPLEPIM_ARTIFACTS` or
/// `<crate root>/artifacts`.
fn default_artifact_dir() -> std::path::PathBuf {
    crate::util::settings::artifacts_from_env()
        .unwrap_or_else(|| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

/// The runtime: PJRT CPU client + compiled-executable cache.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<std::collections::HashMap<String, xla::PjRtLoadedExecutable>>,
    stats: RefCell<ExecStats>,
}

#[cfg(feature = "pjrt")]
impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("cached_executables", &self.cache.borrow().len())
            .field("stats", &self.stats.borrow())
            .finish_non_exhaustive()
    }
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Load the manifest in `dir` and start a PJRT CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            manifest,
            cache: RefCell::new(std::collections::HashMap::new()),
            stats: RefCell::new(ExecStats::default()),
        })
    }

    /// Default artifact directory: `$SIMPLEPIM_ARTIFACTS` or
    /// `<crate root>/artifacts`.
    pub fn default_dir() -> std::path::PathBuf {
        default_artifact_dir()
    }

    pub fn stats(&self) -> ExecStats {
        *self.stats.borrow()
    }

    /// Compile (or fetch from cache) the executable for `name`.
    fn executable(&self, name: &str) -> Result<()> {
        use crate::error::Error;
        if self.cache.borrow().contains_key(name) {
            return Ok(());
        }
        let meta = self.manifest.by_name(name)?;
        let path = self.manifest.hlo_path(meta);
        let path_str = path
            .to_str()
            .ok_or_else(|| Error::Artifact(format!("non-utf8 path {}", path.display())))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.stats.borrow_mut().compiles += 1;
        self.cache.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute artifact `name` on int32 inputs; returns the flattened
    /// int32 outputs in declaration order.
    #[allow(unsafe_code)] // zero-copy i32->byte view for the literal constructor
    pub fn execute_i32(&self, name: &str, inputs: &[TensorRef<'_>]) -> Result<Vec<Vec<i32>>> {
        use crate::error::Error;
        use std::time::Instant;
        let meta = self.manifest.by_name(name)?;
        self.check_inputs(meta, inputs)?;
        self.executable(name)?;

        let t0 = Instant::now();
        let literals = inputs
            .iter()
            .map(|t| {
                // Zero-copy view of the i32 data as bytes; the literal
                // constructor copies once into XLA-owned memory.
                let bytes = unsafe {
                    std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S32,
                    t.shape,
                    bytes,
                )
            })
            .collect::<std::result::Result<Vec<_>, _>>()?;
        let t1 = Instant::now();

        let cache = self.cache.borrow();
        let exe = cache.get(name).expect("compiled above");
        let result = exe.execute::<xla::Literal>(&literals)?;
        let t2 = Instant::now();

        let mut outs = Vec::with_capacity(meta.outputs.len());
        if meta.outputs.len() == 1 {
            // Single-output executables are lowered un-tupled (aot.py):
            // one device->host literal, one literal->vec copy.  (The
            // TFRT CPU client does not implement CopyRawToHost, so the
            // fully zero-intermediate path is unavailable; see
            // EXPERIMENTS.md §Perf.)
            let lit = result[0][0].to_literal_sync()?;
            let v = lit.to_vec::<i32>()?;
            if v.len() != meta.outputs[0].elems() {
                return Err(Error::Artifact(format!(
                    "{name}: output has {} elems, manifest says {}",
                    v.len(),
                    meta.outputs[0].elems()
                )));
            }
            outs.push(v);
        } else {
            // Multi-output (kmeans): tuple literal, decomposed.
            let tuple = result[0][0].to_literal_sync()?;
            let parts = tuple.to_tuple()?;
            if parts.len() != meta.outputs.len() {
                return Err(Error::Artifact(format!(
                    "{name}: expected {} outputs, executable returned {}",
                    meta.outputs.len(),
                    parts.len()
                )));
            }
            for (part, om) in parts.iter().zip(&meta.outputs) {
                let v = part.to_vec::<i32>()?;
                if v.len() != om.elems() {
                    return Err(Error::Artifact(format!(
                        "{name}: output has {} elems, manifest says {}",
                        v.len(),
                        om.elems()
                    )));
                }
                outs.push(v);
            }
        }
        let t3 = Instant::now();

        let mut s = self.stats.borrow_mut();
        s.calls += 1;
        s.literal_s += (t1 - t0).as_secs_f64();
        s.execute_s += (t2 - t1).as_secs_f64();
        s.readback_s += (t3 - t2).as_secs_f64();
        Ok(outs)
    }

    fn check_inputs(
        &self,
        meta: &super::artifact::ArtifactMeta,
        inputs: &[TensorRef<'_>],
    ) -> Result<()> {
        use crate::error::Error;
        if inputs.len() != meta.inputs.len() {
            return Err(Error::Artifact(format!(
                "{}: expected {} inputs, got {}",
                meta.name,
                meta.inputs.len(),
                inputs.len()
            )));
        }
        for (i, (t, im)) in inputs.iter().zip(&meta.inputs).enumerate() {
            if t.shape != im.shape.as_slice() {
                return Err(Error::Artifact(format!(
                    "{}: input {i} shape {:?} != manifest {:?}",
                    meta.name, t.shape, im.shape
                )));
            }
            if t.data.len() != im.elems() {
                return Err(Error::Artifact(format!(
                    "{}: input {i} has {} elems, shape wants {}",
                    meta.name,
                    t.data.len(),
                    im.elems()
                )));
            }
        }
        Ok(())
    }
}

/// Stub runtime compiled when the `pjrt` feature is off: loading always
/// fails with a descriptive error, so `PimSystem::new` callers fall
/// back to host execution.  The type still exposes the full executor
/// API so the coordinator's XLA dispatch paths type-check identically
/// in both builds.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    pub manifest: Manifest,
    stats: RefCell<ExecStats>,
}

#[cfg(not(feature = "pjrt"))]
impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime").field("stats", &self.stats.borrow()).finish_non_exhaustive()
    }
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Surface the artifacts error first (so `make artifacts` guidance
    /// still appears), then report the missing feature.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let _manifest = Manifest::load(dir)?;
        Err(crate::error::Error::Xla(
            "PJRT execution requires the `pjrt` cargo feature (vendored xla-rs); \
             kernels run through the host goldens instead"
                .into(),
        ))
    }

    /// Default artifact directory: `$SIMPLEPIM_ARTIFACTS` or
    /// `<crate root>/artifacts`.
    pub fn default_dir() -> std::path::PathBuf {
        default_artifact_dir()
    }

    pub fn stats(&self) -> ExecStats {
        *self.stats.borrow()
    }

    pub fn execute_i32(&self, name: &str, _inputs: &[TensorRef<'_>]) -> Result<Vec<Vec<i32>>> {
        Err(crate::error::Error::Xla(format!(
            "cannot execute `{name}`: built without the `pjrt` feature"
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Integration tests that require built artifacts live in
    // rust/tests/; here we only test the runtime-independent pieces.
    #[test]
    fn tensor_ref_is_cheap() {
        let d = vec![1i32, 2, 3, 4];
        let t = TensorRef::new(&d, &[2, 2]);
        assert_eq!(t.data.len(), 4);
        assert_eq!(t.shape, &[2, 2]);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_load_reports_missing_feature_or_artifacts() {
        // Nonexistent dir: the artifacts error wins (actionable first).
        let err = Runtime::load("/nonexistent/artifact/dir").unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }
}
