//! # SimplePIM — a software framework for processing-in-memory
//!
//! Reproduction of *SimplePIM: A Software Framework for Productive and
//! Efficient Processing-in-Memory* (Chen et al., 2023) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the SimplePIM framework itself: the
//!   management, communication, and processing interfaces
//!   ([`coordinator`]), running against a simulated UPMEM-like machine
//!   ([`pim`]) and executing workload kernels through AOT-compiled XLA
//!   executables ([`runtime`], behind the `pjrt` feature; the
//!   bit-identical host goldens serve otherwise).  The request path is
//!   plan-based: iterator calls build a lazy op graph
//!   ([`coordinator::plan`]) that the optimizer
//!   ([`coordinator::optimizer`]) fuses (map→map, map→red), prunes
//!   (dead-intermediate elision), and caches (LRU reduction plans)
//!   before anything is charged to the device model.  Kernel launches
//!   and the scatter/gather marshalling loops dispatch through an
//!   execution backend ([`backend`]): the sequential walk, explicit
//!   gang batching, or a rank-sharded `std::thread::scope` worker pool
//!   (`--backend parallel --threads N`) — bit-identical results and
//!   identical modeled time on all three.
//! * **L2/L1 (build time)** — `python/compile/` holds the JAX compute
//!   graphs and Pallas kernels, lowered once to `artifacts/*.hlo.txt`.
//!   Python never runs on the request path.
//!
//! See DESIGN.md for the system inventory and the per-experiment index,
//! and EXPERIMENTS.md for paper-vs-measured results.

// Crate-wide hardening (DESIGN.md §19): unsafe code is denied except
// for the four audited LE-marshalling fast paths and the PJRT literal
// view, each carrying a scoped allow + SAFETY comment.
#![deny(unsafe_code)]
#![warn(missing_debug_implementations, rust_2018_idioms)]

pub mod analysis;
pub mod backend;
pub mod cli;
pub mod coordinator;
pub mod error;
pub mod pim;
pub mod report;
pub mod runtime;
pub mod timing;
pub mod util;
pub mod workloads;

pub use analysis::AnalyzeMode;
pub use coordinator::PimSystem;
pub use error::{Error, Result};
pub use pim::PimConfig;
